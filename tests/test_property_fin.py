"""Property-based tests (hypothesis) for the FIN framework invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (AppRequirements, Network, build_extended_graph,
                        build_feasible_graph, evaluate_config, make_network,
                        solve_fin, solve_mcp, solve_opt, synthetic_profile)
from repro.core.bellman_ford import (batched_banded_relax_min,
                                     batched_layered_relax_min,
                                     bellman_ford_np, layered_relax,
                                     minplus_vecmat_np)
from repro.core.tolerances import RELAX_RTOL_F32

SETTINGS = settings(max_examples=25, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


def _random_network(seed: int, n_extra: int = 0) -> Network:
    rng = np.random.default_rng(seed)
    tiers = ["mobile", "edge", "cloud"] + ["edge"] * n_extra
    frac = rng.uniform(1e-4, 1e-2, len(tiers))
    frac[0] = rng.uniform(1e-4, 5e-3)
    nw = make_network(tuple(tiers), compute_frac=frac,
                      bw_frac=float(rng.uniform(0.001, 0.01)))
    return nw


@given(seed=st.integers(0, 10_000), n_blocks=st.integers(2, 6),
       gamma=st.sampled_from([4, 10, 25]))
@SETTINGS
def test_competitive_ratio_property(seed, n_blocks, gamma):
    """Property 2: FIN cost <= (1 + 1/gamma) * Opt cost, whenever Opt is feasible."""
    rng = np.random.default_rng(seed)
    prof = synthetic_profile(n_blocks, min(n_blocks, int(rng.integers(1, 4))),
                             seed=seed)
    nw = _random_network(seed)
    alpha = float(rng.uniform(0.0, max(e.accuracy for e in prof.exits)))
    delta = float(rng.uniform(1e-3, 50e-3))
    req = AppRequirements(alpha=alpha, delta=delta, sigma=1.0)
    opt = solve_opt(nw, prof, req)
    fin = solve_fin(nw, prof, req, gamma=gamma)
    if opt.feasible:
        assert fin.feasible, "FIN must find a solution when Opt does"
        assert fin.energy <= opt.energy * (1 + 1.0 / gamma) + 1e-12
    if fin.feasible:
        # FIN never beats the optimum (it is exact on the quantized graph)
        assert fin.energy >= opt.energy - 1e-12


@given(seed=st.integers(0, 10_000), n_blocks=st.integers(2, 6))
@SETTINGS
def test_fin_output_always_honours_constraints(seed, n_blocks):
    """Whatever FIN returns re-evaluates as feasible (its defining invariant)."""
    rng = np.random.default_rng(seed)
    prof = synthetic_profile(n_blocks, 2 if n_blocks >= 2 else 1, seed=seed + 1)
    nw = _random_network(seed + 2)
    req = AppRequirements(alpha=float(rng.uniform(0, 1)),
                          delta=float(rng.uniform(5e-4, 20e-3)))
    sol = solve_fin(nw, prof, req, gamma=10)
    if sol.found:
        ev = evaluate_config(nw, prof, req, sol.config)
        assert ev.feasible, ev.violations
        assert ev.energy == pytest.approx(sol.energy)


@given(seed=st.integers(0, 10_000))
@SETTINGS
def test_survival_accounting(seed):
    """phi accounting: survival is monotone non-increasing, in [0, 1], and the
    effective phi of any final exit sums to 1."""
    rng = np.random.default_rng(seed)
    n_blocks = int(rng.integers(2, 8))
    prof = synthetic_profile(n_blocks, int(rng.integers(1, min(4, n_blocks + 1))),
                             seed=seed)
    for k in range(prof.n_exits):
        phi = prof.effective_phi(k)
        assert phi.sum() == pytest.approx(1.0)
        assert (phi >= -1e-12).all()
        prev = 1.0
        for i in range(prof.exits[k].block + 1):
            s_in = prof.survival_entering_block(i, k)
            s_out = prof.survival_after_block(i, k)
            assert -1e-12 <= s_out <= s_in <= prev + 1e-12
            prev = s_in
        assert prof.survival_after_block(prof.exits[k].block, k) == pytest.approx(0.0)


@given(seed=st.integers(0, 10_000))
@SETTINGS
def test_expected_ops_monotone_in_exit_depth(seed):
    rng = np.random.default_rng(seed)
    prof = synthetic_profile(int(rng.integers(3, 8)), 3, seed=seed)
    ops = [prof.expected_ops(k) for k in range(prof.n_exits)]
    assert all(b >= a - 1e-9 for a, b in zip(ops, ops[1:]))


@given(seed=st.integers(0, 10_000), size=st.integers(2, 40))
@SETTINGS
def test_minplus_identity_and_bf(seed, size):
    """(min,+) algebra: relaxation with the tropical identity is a no-op, and
    Bellman-Ford on a DAG equals the layered DP."""
    rng = np.random.default_rng(seed)
    W = rng.uniform(0.1, 5.0, (size, size))
    W[rng.uniform(size=(size, size)) < 0.5] = np.inf
    ident = np.full((size, size), np.inf)
    np.fill_diagonal(ident, 0.0)
    d = rng.uniform(0, 10, size)
    out, _ = minplus_vecmat_np(d, ident)
    np.testing.assert_allclose(out, d)
    # BF from a single source terminates and is stable under one more iter
    dist, _ = bellman_ford_np(np.triu(W, 1) + np.tril(ident, 0), 0)
    again, _ = minplus_vecmat_np(dist, np.triu(W, 1) + np.tril(ident, 0))
    assert (again >= dist - 1e-12).all()


@given(seed=st.integers(0, 5_000), S=st.integers(2, 24), L=st.integers(1, 6))
@SETTINGS
def test_layered_relax_backends_agree(seed, S, L):
    rng = np.random.default_rng(seed)
    Ws = rng.uniform(0.1, 5.0, (L, S, S))
    Ws[rng.uniform(size=Ws.shape) < 0.4] = np.inf
    init = rng.uniform(0, 3, S)
    init[rng.uniform(size=S) < 0.3] = np.inf
    d_np = layered_relax(init, Ws, backend="numpy")
    d_jnp = layered_relax(init, Ws, backend="jnp")
    mask = np.isfinite(d_np)
    assert (np.isfinite(d_jnp) == mask).all()
    np.testing.assert_allclose(d_np[mask], d_jnp[mask], rtol=RELAX_RTOL_F32)


@given(seed=st.integers(0, 10_000), n_blocks=st.integers(2, 6),
       gamma=st.sampled_from([3, 10, 25]),
       quantize=st.sampled_from(["ceil", "floor", "round"]))
@SETTINGS
def test_banded_dense_python_dp_equivalence(seed, n_blocks, gamma, quantize):
    """The PR-2 invariant: banded, dense, and python-oracle DPs agree on
    random networks — identical distances (bit-exact, float64), identical
    argmin backtrack paths, and identical selected configurations — across
    every quantize mode and the paper's gamma range."""
    from repro.core.fin import _BandedDP, _FlatDP, _backtrack, _run_dp

    rng = np.random.default_rng(seed)
    prof = synthetic_profile(n_blocks, min(n_blocks, int(rng.integers(1, 4))),
                             seed=seed)
    nw = _random_network(seed + 3, n_extra=int(rng.integers(0, 3)))
    req = AppRequirements(alpha=float(rng.uniform(0, 0.8)),
                          delta=float(rng.uniform(1e-3, 30e-3)))

    # distance level: banded == dense bit for bit, both == python oracle
    ext = build_extended_graph(nw, prof, req)
    fg = build_feasible_graph(ext, gamma, quantize=quantize)
    N, G = ext.n_nodes, gamma
    E, st_ = fg.banded_tensors()
    hb = batched_banded_relax_min(fg.init_grid()[None], E[None], st_[None],
                                  fg.depth_window_lo)[0]
    Ws = fg.layer_matrices()
    hd = batched_layered_relax_min(fg.init_vector()[None], Ws[None])[0]
    np.testing.assert_array_equal(hb.reshape(hb.shape[0], -1), hd)
    oracle_dp = _run_dp(fg)
    np.testing.assert_array_equal(hb, oracle_dp.dist[..., 0])

    # argmin-path level: every finite end state backtracks identically
    banded = _BandedDP(hb, E, st_, fg.depth_window_lo)
    flat = _FlatDP(hd, Ws, N, G)
    L = hb.shape[0]
    ends = np.argwhere(np.isfinite(hb[L - 1]))
    for n, g in ends[:8]:
        pb = _backtrack(banded, L - 1, int(n), int(g), 0)
        pd = _backtrack(flat, L - 1, int(n), int(g), 0)
        po = _backtrack(oracle_dp, L - 1, int(n), int(g), 0)
        assert pb == pd == po

    # solver level: selected configs identical across the three backends
    sols = {b: solve_fin(nw, prof, req, gamma=gamma, quantize=quantize,
                         backend=b)
            for b in ("python", "minplus", "dense")}
    ref = sols["python"]
    for b in ("minplus", "dense"):
        s = sols[b]
        assert s.found == ref.found, b
        if ref.found:
            assert s.config.placement == ref.config.placement, b
            assert s.config.final_exit == ref.config.final_exit, b
            assert s.energy == ref.energy, b


@given(seed=st.integers(0, 10_000))
@SETTINGS
def test_mcp_vs_fin_energy_dominance(seed):
    """When both are feasible, FIN's energy is never worse than MCP's (FIN
    optimizes energy directly; MCP optimizes the auxiliary Omega weight)."""
    rng = np.random.default_rng(seed)
    n_blocks = int(rng.integers(2, 7))
    prof = synthetic_profile(n_blocks, int(rng.integers(1, min(4, n_blocks + 1))),
                             seed=seed)
    nw = _random_network(seed + 7)
    req = AppRequirements(alpha=float(rng.uniform(0, 0.8)),
                          delta=float(rng.uniform(1e-3, 30e-3)))
    fin = solve_fin(nw, prof, req, gamma=16)
    mcp = solve_mcp(nw, prof, req)
    if fin.feasible and mcp.feasible:
        assert fin.energy <= mcp.energy * (1 + 1.0 / 16) + 1e-12
