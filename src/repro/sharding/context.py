"""Explicit sharding context for model-internal sharding constraints.

Model code (e.g. sequence-parallel activation constraints) must not depend
on driver details; drivers enter ``activation_sharding(mesh)`` and the model
queries ``current()``.  Absent a context (unit tests, single-device runs),
constraints are no-ops.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Tuple

_STATE = threading.local()


@dataclass(frozen=True)
class ShardCtx:
    dp_axes: Tuple[str, ...]
    model_axis: str
    model_size: int
    dp_size: int = 1


def current() -> Optional[ShardCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = 1
    for a in dp_axes:
        dp_size *= axes[a]
    ctx = ShardCtx(
        dp_axes=dp_axes,
        model_axis="model" if "model" in axes else "",
        model_size=axes.get("model", 1),
        dp_size=dp_size,
    )
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev
