"""Oracle for the decode_attn kernel: the serving engine's own jnp path."""
import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention


def decode_attn_ref(q, k_cache, v_cache, cache_pos, pos, *, window: int = 0):
    """q: [B, H, D] -> [B, H, D] via models.attention.decode_attention."""
    out = decode_attention(q[:, None], k_cache, v_cache, cache_pos, pos,
                           window=window)
    return out[:, 0]
