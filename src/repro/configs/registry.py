"""Architecture registry: the 10 assigned architectures (+ reduced variants).

Every entry carries its public-literature source tag.  ``get(name)`` returns
the full config; ``get(name, reduced=True)`` the CPU smoke-test variant.
"""
from __future__ import annotations

from typing import Dict, List

from .base import SHAPES, ArchConfig, LayerSpec, ShapeSpec

A = LayerSpec("attn", "dense")


def _jamba() -> ArchConfig:
    # [arXiv:2403.19887; hf] — Mamba+attention 1:7 interleave, MoE 16e top-2
    # (MoE on alternate layers; attention at position 4 of each 8-layer block).
    pattern = tuple(
        LayerSpec("attn" if i == 4 else "ssm",
                  "moe" if i % 2 == 1 else "dense")
        for i in range(8))
    return ArchConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
        vocab_size=65536, pattern=pattern, head_dim=128,
        n_experts=16, top_k=2, ssm_state=128, ssm_head_dim=64,
        expert_parallel=True, fsdp=True, master_weights=False,
        remat="full")


def _phi3() -> ArchConfig:
    # [arXiv:2404.14219; unverified] — dense, RoPE SwiGLU GQA (40H, kv=10)
    return ArchConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
        vocab_size=100352, pattern=(A,), head_dim=128)


def _qwen3() -> ArchConfig:
    # [hf:Qwen/Qwen3-8B; hf] — dense, qk_norm, GQA kv=8
    return ArchConfig(
        name="qwen3-4b", family="dense",
        n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, d_ff=9728,
        vocab_size=151936, pattern=(A,), head_dim=80, qk_norm=True)


def _minitron() -> ArchConfig:
    # [arXiv:2407.14679; hf] — pruned nemotron, GQA kv=8
    return ArchConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
        vocab_size=256000, pattern=(A,), head_dim=128)


def _granite() -> ArchConfig:
    # [arXiv:2405.04324; hf] — llama-arch code model, MQA (kv=1)
    return ArchConfig(
        name="granite-34b", family="dense",
        n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
        vocab_size=49152, pattern=(A,), head_dim=128,
        kv_shard_mode="sequence")


def _hubert() -> ArchConfig:
    # [arXiv:2106.07447; unverified] — encoder-only audio; frame-label head
    return ArchConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
        vocab_size=504, pattern=(A,), head_dim=80,
        causal=False, has_decoder=False, frontend="audio",
        vocab_pad_multiple=512)


def _arctic() -> ArchConfig:
    # [hf:Snowflake/snowflake-arctic-base; hf] — 128e top-2 + dense residual
    return ArchConfig(
        name="arctic-480b", family="moe",
        n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
        vocab_size=32000, pattern=(LayerSpec("attn", "moe"),), head_dim=128,
        n_experts=128, top_k=2, moe_dense_residual=True,
        dense_residual_d_ff=14336,
        expert_parallel=True, fsdp=True, master_weights=False,
        remat="full")


def _mixtral() -> ArchConfig:
    # [arXiv:2401.04088; hf] — 8 experts top-2, sliding-window attention
    return ArchConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab_size=32768, pattern=(LayerSpec("attn", "moe"),), head_dim=128,
        n_experts=8, top_k=2, sliding_window=4096,
        fsdp=True, remat="full")


def _mamba2() -> ArchConfig:
    # [arXiv:2405.21060; unverified] — SSD, attention-free, no MLP
    return ArchConfig(
        name="mamba2-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab_size=50280, pattern=(LayerSpec("ssm", "none"),),
        ssm_state=128, ssm_head_dim=64, tie_embeddings=True)


def _internvl2() -> ArchConfig:
    # [arXiv:2404.16821; hf] — InternViT (stub) + InternLM2 backbone
    return ArchConfig(
        name="internvl2-2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
        vocab_size=92553, pattern=(A,), head_dim=128,
        frontend="vision", n_patches=1024)


#: Beyond-paper optimized sharding/runtime defaults discovered in the §Perf
#: hillclimb (EXPERIMENTS.md).  The base configs stay paper-faithful
#: (Megatron TP x DP); `get(name, optimized=True)` applies these.
OPTIMIZED_OVERRIDES = {
    # small dense models: 16-way TP is 6.8x collective-overhead — pure
    # DP/ZeRO-3 over all chips makes them compute-bound.
    "qwen3-4b": dict(parallelism_mode="pure_dp"),
    "internvl2-2b": dict(parallelism_mode="pure_dp"),
    "mamba2-1.3b": dict(parallelism_mode="pure_dp"),
    "hubert-xlarge": dict(parallelism_mode="pure_dp"),
    # mid/large dense: keep TP, add sequence parallelism (bf16 ag/rs +
    # activation sharding).
    "phi3-medium-14b": dict(seq_parallel=True),
    "minitron-8b": dict(seq_parallel=True),
    "granite-34b": dict(seq_parallel=True, kv_cache_dtype="int8"),
    "mixtral-8x22b": dict(seq_parallel=True),
    "arctic-480b": dict(seq_parallel=True),
    # hybrid giant: + SSD head sharding (16x replicated-compute fix).
    # (per-layer remat was tried and REFUTED: no memory win, +25% recompute
    # — §Perf iteration log.)
    "jamba-1.5-large-398b": dict(seq_parallel=True, ssm_head_shard=True),
}

_BUILDERS = {
    "jamba-1.5-large-398b": _jamba,
    "phi3-medium-14b": _phi3,
    "qwen3-4b": _qwen3,
    "minitron-8b": _minitron,
    "granite-34b": _granite,
    "hubert-xlarge": _hubert,
    "arctic-480b": _arctic,
    "mixtral-8x22b": _mixtral,
    "mamba2-1.3b": _mamba2,
    "internvl2-2b": _internvl2,
}

ARCH_NAMES: List[str] = list(_BUILDERS)


def get(name: str, *, reduced: bool = False,
        optimized: bool = False) -> ArchConfig:
    cfg = _BUILDERS[name]()
    if optimized:
        import dataclasses
        cfg = dataclasses.replace(cfg, **OPTIMIZED_OVERRIDES.get(name, {}))
    return cfg.reduced() if reduced else cfg


def sub_quadratic(cfg: ArchConfig) -> bool:
    """Eligibility for long_500k: SSM/hybrid, or bounded-window attention."""
    kinds = {s.kind for s in cfg.pattern}
    if kinds == {"ssm"}:
        return True
    if "ssm" in kinds:
        return True        # hybrid: attention KV is 1/8 of layers
    return cfg.sliding_window > 0


def runnable_cells(arch: str) -> List[str]:
    """The (arch x shape) cells that are well-defined for this arch."""
    cfg = get(arch)
    cells = ["train_4k", "prefill_32k"]
    if cfg.has_decoder:
        cells.append("decode_32k")
        if sub_quadratic(cfg):
            cells.append("long_500k")
    return cells


def all_cells() -> List[tuple]:
    return [(a, s) for a in ARCH_NAMES for s in runnable_cells(a)]


def skipped_cells() -> List[tuple]:
    out = []
    for a in ARCH_NAMES:
        run = set(runnable_cells(a))
        for s in SHAPES:
            if s not in run:
                reason = ("encoder-only (no autoregressive step)"
                          if not get(a).has_decoder
                          else "pure full attention (no sub-quadratic path)")
                out.append((a, s, reason))
    return out
