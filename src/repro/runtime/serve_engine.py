"""Split-serving engine: exit-aware continuous batching over a FIN placement.

This is the TPU-native adaptation of the paper's execution model
(DESIGN.md Sec. 3): SPMD cannot stop computing individual batch lanes, so
per-sample early exits are realized as *scheduling*:

  * every decode step runs the full stack once for the active batch;
  * the fused gate (kernels/ee_gate) scores each exit's logits; a sequence
    whose confidence clears its threshold takes THAT exit's token — deeper
    blocks' output for it is discarded;
  * finished sequences free their slot immediately and the next queued
    request takes it (continuous batching) — phi-fraction compute saving
    becomes throughput;
  * per-token *tier accounting*: with a FIN placement (blocks -> tiers),
    the engine charges each token only the blocks up to its exit, yielding
    the measured energy the paper's objective (3a) predicts;
  * fault tolerance: the placement lives in a persistent ``core.Plan`` —
    ``fail_node`` masks the dead node and issues a *warm* re-solve (no
    graph reconstruction; bit-exact vs a cold solve on the reduced
    network), ``recover_node`` unmasks and re-solves; node indices stay
    stable across failures (Sec. V elasticity).  Every failover re-split
    also exposes the scenario's Pareto frontier (``engine.frontier``,
    core/frontier.py), and with ``migration_weight > 0`` the re-split is
    frontier-aware: the engine deploys the frontier row minimizing
    ``energy + migration_weight * migration_bits`` — on recovery that can
    keep the current placement instead of migrating everything back for a
    marginal energy win;
  * O(1) failover (``contingency=True``): a ``core.contingency``
    library precomputes the likely failure masks' solutions/frontiers/
    migration prices around the current state, so a covered ``fail_node``
    / ``recover_node`` installs the precomputed entry — ZERO DP
    relaxations on the critical path, bit-exact vs the warm re-solve —
    and refills the library off the critical path (the next ``step()``);
    uncovered or environment-stale masks fall back to the warm re-solve
    and record the miss;
  * graceful degradation: when no feasible placement survives a failure,
    ``on_infeasible`` picks the policy — ``"raise"`` a typed
    ``NoFeasiblePlacement`` (carries the masked set + last feasible
    frontier), ``"pause"`` park in-flight requests until a recovery, or
    ``"degrade"`` deploy the cheapest row of the last feasible frontier
    avoiding the dead nodes (falls back to pausing when every row routes
    through one);
  * churn-driven serving: ``on_tick`` applies a ``scenarios.churn_trace``
    tick — uplink fades re-split mid-serving behind a hysteresis band,
    failures/recoveries hit the contingency library — and
    ``serve_with_churn`` interleaves ticks with decode steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (AppRequirements, Config, DNNProfile, Network,
                        ParetoFrontier, Plan, evaluate_config,
                        migration_delta)
from repro.core.contingency import (ContingencyEntry, ContingencyLibrary,
                                    NoFeasiblePlacement)
from repro.core.frontier import frontier_pick
from repro.core.scenarios import MOBILE_UPLINK_BPS, ChurnEvent
from repro.kernels.ee_gate.ops import ee_gate
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    tokens: List[int] = field(default_factory=list)
    exits_taken: List[int] = field(default_factory=list)  # exit idx per token
    done: bool = False


@dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    exit_histogram: Dict[int, int] = field(default_factory=dict)
    blocks_executed: int = 0          # tier-charged block executions
    blocks_saved: int = 0             # skipped by early exits
    energy_j: float = 0.0             # placement-model energy (Eq. 2 units)
    replacements: int = 0             # FIN re-solves after failures/recovery
    blocks_migrated: int = 0          # blocks re-hosted by re-placements
    migration_bits: float = 0.0       # state bits moved by re-placements
    contingency_hits: int = 0         # failovers served from the library
    contingency_misses: int = 0       # failovers that warm re-solved
    paused_events: int = 0            # infeasible -> serving parked
    degrades: int = 0                 # infeasible -> degraded frontier row

    @property
    def measured_phi(self) -> Dict[int, float]:
        tot = max(1, sum(self.exit_histogram.values()))
        return {k: v / tot for k, v in sorted(self.exit_histogram.items())}


class SplitServeEngine:
    """Decode engine with exit-aware continuous batching.

    Prompts are consumed token-by-token through the decode path (prefill-as-
    decode keeps slot cache surgery trivial); generation then proceeds with
    gated exits.  ``placement``/``profile``/``network`` wire the engine to
    the paper's placement problem for energy accounting; they are optional —
    without them the engine is a plain continuous-batching server.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int,
                 cache_len: int, thresholds: Optional[Sequence[float]] = None,
                 network: Optional[Network] = None,
                 profile: Optional[DNNProfile] = None,
                 req: Optional[AppRequirements] = None,
                 gamma: int = 10, seed: int = 0,
                 migration_weight: float = 0.0, frontier_k: int = 4,
                 on_infeasible: str = "raise", contingency: bool = True,
                 hysteresis: float = 0.05):
        assert cfg.has_decoder
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.n_exits = len(cfg.exit_layer_list) + 1
        self.thresholds = list(thresholds) if thresholds is not None else \
            [0.9] * (self.n_exits - 1)
        self.caches = T.init_caches(cfg, batch_size, cache_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, t, c, pos))
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.stats = EngineStats()
        self.pos = 0
        self._slot_len = np.zeros(batch_size, np.int32)
        # placement integration: a persistent Plan owns the built pipeline
        # state, so failure/recovery re-solves are warm deltas
        self.profile = profile
        self.app_req = req
        self.gamma = gamma
        self.plan: Optional[Plan] = None
        self.placement: Optional[Config] = None
        self.network = network
        if migration_weight < 0:
            raise ValueError(f"migration_weight must be >= 0, got "
                             f"{migration_weight}")
        if frontier_k < 1:
            raise ValueError(f"frontier_k must be >= 1, got {frontier_k}")
        self.migration_weight = float(migration_weight)
        self.frontier_k = int(frontier_k)
        if on_infeasible not in ("raise", "pause", "degrade"):
            raise ValueError(f"on_infeasible must be 'raise', 'pause' or "
                             f"'degrade', got {on_infeasible!r}")
        if hysteresis < 0:
            raise ValueError(f"hysteresis must be >= 0, got {hysteresis}")
        self.on_infeasible = on_infeasible
        self.hysteresis = float(hysteresis)
        #: graceful-degradation state: ``paused`` parks serving (step() is
        #: a no-op) until a topology/channel change restores feasibility;
        #: ``degraded`` flags a placement adopted off the last feasible
        #: frontier instead of a fresh solve
        self.paused = False
        self.degraded = False
        self._ref_energy = np.inf          # hysteresis reference (on_tick)
        self._last_feasible_frontier: Optional[ParetoFrontier] = None
        #: the Pareto frontier of the last (re-)placement — refreshed on
        #: every failover / recovery re-split (core/frontier.py)
        self.frontier: Optional[ParetoFrontier] = None
        #: precomputed-failover library (core/contingency.py), refilled off
        #: the failover critical path; None when placement is not wired or
        #: ``contingency=False``
        self.contingency: Optional[ContingencyLibrary] = None
        self._contingency_dirty = False
        if network is not None and profile is not None and req is not None:
            self.plan = Plan(network, profile, req, gamma=gamma)
            sol = self.plan.solve()
            assert sol.feasible, "no feasible FIN placement"
            self.placement = sol.config
            self.frontier = self.plan.frontier(k_per_exit=self.frontier_k)
            self.network = self.plan.network   # live view of current state
            self._ref_energy = sol.energy
            if len(self.frontier):
                self._last_feasible_frontier = self.frontier
            if contingency:
                self.contingency = ContingencyLibrary(
                    self.plan, k_per_exit=self.frontier_k)
                self.contingency.refill(base_config=self.placement)

    # ------------------------------------------------------------------ API
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> Request:
        r = Request(rid=len(self.queue) + 10_000, prompt=list(prompt),
                    max_new_tokens=max_new_tokens)
        self.queue.append(r)
        return r

    def _require_plan(self) -> None:
        if self.plan is None:
            raise RuntimeError(
                "engine has no placement plan: construct SplitServeEngine "
                "with network=, profile= and req= to enable failover")

    def _check_node(self, node_idx: int) -> int:
        if not isinstance(node_idx, (int, np.integer)):
            raise ValueError(f"node_idx must be an integer, got "
                             f"{type(node_idx).__name__}")
        n = int(node_idx)
        if not 0 <= n < self.plan.n_nodes:
            raise ValueError(f"node_idx {n} out of range for the "
                             f"{self.plan.n_nodes}-node network")
        return n

    def fail_node(self, node_idx: int) -> None:
        """Node failure: mask the node and re-split.

        The plan keeps its node indexing (the placement simply avoids the
        dead node), so tier accounting and any in-flight references stay
        valid.  With the contingency library covering the resulting mask
        the new placement is *installed* — zero DP relaxations, bit-exact
        vs the warm re-solve; otherwise this is the warm re-solve (cached
        pipeline state; bit-exact vs a cold solve on the reduced
        network), and the miss is recorded."""
        self.fail_nodes([node_idx])

    def fail_nodes(self, node_idxs: Sequence[int]) -> None:
        """Simultaneous (correlated) failure of several nodes: ONE joint
        mask, ONE lookup/re-solve, ONE re-split — a tier-wide outage whose
        joint mask the library covers is as O(1) as a single failure."""
        self._require_plan()
        nodes = [self._check_node(n) for n in node_idxs]
        src = self.plan.network.source_node
        if src in nodes:
            raise ValueError("cannot mask the source-hosting node")
        if not nodes:
            return
        prospective = self.plan._masked.copy()
        prospective[nodes] = True
        entry = (self.contingency.lookup(prospective)
                 if self.contingency is not None else None)
        for n in nodes:
            self.plan.mask_node(n)
        self._after_topology(entry)

    def recover_node(self, node_idx: int) -> None:
        """Node recovery: unmask and re-split (may migrate back) — same
        library-hit / warm-fallback protocol as ``fail_node``."""
        self._require_plan()
        n = self._check_node(node_idx)
        prospective = self.plan._masked.copy()
        prospective[n] = False
        entry = (self.contingency.lookup(prospective)
                 if self.contingency is not None else None)
        self.plan.unmask_node(n)
        self._after_topology(entry)

    def _after_topology(self, entry: Optional[ContingencyEntry]) -> None:
        """Re-split after a mask change: install the library entry (hit:
        zero DP relaxations, migration pre-priced) or warm re-solve
        (miss).  Either way the library is now keyed off a stale base
        mask — mark it dirty; the refill runs OFF this critical path, at
        the next serving step / explicit ``refresh_contingency``."""
        if entry is not None:
            self.stats.contingency_hits += 1
            sol = self.plan.install_solution(entry.solution, dps=entry.dps)
            self._resplit(sol, entry.frontier, priced=entry)
        else:
            if self.contingency is not None:
                self.stats.contingency_misses += 1
            self._replace()
        self._contingency_dirty = True

    def _replace(self) -> None:
        """Warm re-solve + frontier-aware re-split (the library-miss and
        channel-churn path)."""
        sol = self.plan.solve()
        fr = self.plan.frontier(k_per_exit=self.frontier_k)
        self._resplit(sol, fr)

    def _resplit(self, sol, fr: ParetoFrontier,
                 priced: Optional[ContingencyEntry] = None) -> None:
        """Deploy a re-solve result (fresh or library-installed).

        The scenario's Pareto frontier is exposed on every re-split
        (``self.frontier``); with ``migration_weight > 0`` the new
        placement is the option minimizing ``energy + migration_weight *
        migration_bits`` over the frontier rows AND the current placement
        (if it is still feasible — after a recovery, keeping the current
        hosts avoids migrating every block back for a marginal win).
        ``migration_weight=0`` deploys the argmin row.  ``priced`` is the
        library entry whose build-time migration price is reused when the
        deployed transition is exactly the priced one."""
        old = self.placement
        self.frontier = fr
        choice = sol.config
        energy = sol.energy
        if self.migration_weight > 0 and old is not None:
            ev_old = self.plan.evaluate(old)
            choice, energy, _moved, _bits, _kept = frontier_pick(
                fr, old, ev_old.feasible, ev_old.energy, self.profile,
                self.migration_weight)
            if choice is not None and (
                    not sol.feasible
                    or choice.placement != sol.config.placement
                    or choice.final_exit != sol.config.final_exit):
                self.plan.adopt(choice)     # a non-argmin frontier choice
        if choice is None:
            self._handle_infeasible(old)
            return
        self.paused = False
        self.degraded = False
        self.placement = choice
        self._ref_energy = energy
        if len(fr):
            self._last_feasible_frontier = fr
        self.stats.replacements += 1
        if (priced is not None and sol.feasible and old is not None
                and priced.base_config is not None
                and old.placement == priced.base_config.placement
                and old.final_exit == priced.base_config.final_exit
                and choice.placement == sol.config.placement
                and choice.final_exit == sol.config.final_exit):
            moved, bits = priced.moved, priced.bits
        else:
            moved, bits = migration_delta(self.profile, old, choice)
        self.stats.blocks_migrated += moved
        self.stats.migration_bits += bits

    def _handle_infeasible(self, old: Optional[Config]) -> None:
        """No feasible placement under the current mask: apply the
        ``on_infeasible`` policy."""
        masked = self.plan.masked_nodes
        if self.on_infeasible == "degrade":
            lf = self._last_feasible_frontier
            row = lf.cheapest_avoiding(masked) if lf is not None else None
            if row is not None:
                self.placement = row.config
                self.plan.adopt(row.config)
                self.degraded = True
                self.paused = False
                self._ref_energy = row.energy
                self.stats.degrades += 1
                self.stats.replacements += 1
                moved, bits = migration_delta(self.profile, old, row.config)
                self.stats.blocks_migrated += moved
                self.stats.migration_bits += bits
                return
            # every historical row routes through a dead node: park instead
        if self.on_infeasible in ("pause", "degrade"):
            self.paused = True
            self.stats.paused_events += 1
            return
        raise NoFeasiblePlacement(masked, self._last_feasible_frontier)

    # ----------------------------------------------------- contingency admin
    def refresh_contingency(self) -> int:
        """Rebuild the contingency library around the current (mask,
        channel) state; returns the number of entries built.  Runs
        automatically before serving steps when the library is dirty or
        environment-stale — call explicitly to control when the (warm,
        off-critical-path) build cost is paid."""
        if self.contingency is None:
            return 0
        n = self.contingency.refill(base_config=self.placement)
        self._contingency_dirty = False
        return n

    def _maybe_refill(self) -> None:
        if self.contingency is not None and (
                self._contingency_dirty or self.contingency.stale):
            self.refresh_contingency()

    # ------------------------------------------------------------ churn tick
    def on_tick(self, events: Sequence[ChurnEvent], *,
                uplink_bps: float = MOBILE_UPLINK_BPS) -> Dict[str, object]:
        """Apply one ``scenarios.churn_trace`` tick to the serving plan.

        Uplink fades rescale the source links (``value`` is the AR(1)
        quality factor on ``uplink_bps``) and re-split only when the
        incumbent placement leaves the hysteresis band (infeasible, or
        energy above ``(1 + hysteresis) * ref``); failures are applied as
        ONE joint mask (a tier outage covered by the library is a single
        O(1) hit) and recoveries individually, all through the
        contingency protocol.  The engine serves a single user — drive it
        with ``churn_trace(n_users=1, p_move=0.0, ...)``; ``attach``
        events raise.  Returns a per-tick report dict.
        """
        self._require_plan()
        fails: List[int] = []
        recovers: List[int] = []
        chan = False
        for ev in events:
            if ev.kind == "fail":
                fails.append(int(ev.value))
            elif ev.kind == "recover":
                recovers.append(int(ev.value))
            elif ev.kind == "uplink":
                self.plan.update_uplink(uplink_bps * float(ev.value))
                chan = True
            elif ev.kind == "slice":
                self.plan.update_slice(ev.value)
                chan = True
            else:
                raise ValueError(
                    f"unsupported churn event kind {ev.kind!r} for the "
                    f"single-user engine (generate traces with p_move=0)")
        resplit = held = False
        if chan:
            if self.paused:
                self._replace()            # re-attempt under the new channel
                resplit = True
            elif self.placement is not None:
                ev_inc = self.plan.evaluate(self.placement)
                if ev_inc.feasible and ev_inc.energy <= \
                        self._ref_energy * (1.0 + self.hysteresis):
                    held = True
                else:
                    self._replace()
                    resplit = True
            # the channel moved: re-key the library NOW so this tick's own
            # failures can still hit precomputed entries
            self._maybe_refill()
        fails = [n for n in fails if not self.plan._masked[n]]
        recovers = [n for n in recovers if self.plan._masked[n]]
        h0 = self.contingency.stats.hits if self.contingency else 0
        m0 = self.contingency.stats.misses if self.contingency else 0
        if fails:
            self.fail_nodes(fails)
            resplit = True
        for n in recovers:
            self.recover_node(n)
            resplit = True
        if fails or recovers:
            self._maybe_refill()
        return {
            "resplit": resplit, "held": held,
            "n_fail": len(fails), "n_recover": len(recovers),
            "contingency_hits":
                (self.contingency.stats.hits if self.contingency else 0) - h0,
            "contingency_misses":
                (self.contingency.stats.misses if self.contingency else 0)
                - m0,
            "paused": self.paused, "degraded": self.degraded,
        }

    def run(self, *, max_steps: int = 10_000) -> EngineStats:
        while (any(self.slots) or self.queue) and not self.paused \
                and self.stats.steps < max_steps:
            self.step()
        return self.stats

    # ----------------------------------------------------------------- step
    def _fill_slots(self) -> None:
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)
                self._slot_len[i] = 0

    def _charge(self, exit_idx: int) -> None:
        """Tier accounting for one emitted token at the given exit."""
        st = self.stats
        st.exit_histogram[exit_idx] = st.exit_histogram.get(exit_idx, 0) + 1
        if self.profile is None or self.placement is None:
            return
        prof, place = self.profile, self.placement
        last_block = prof.exits[min(exit_idx, prof.n_exits - 1)].block
        nw = self.network
        for b in range(prof.n_blocks):
            if b <= last_block:
                st.blocks_executed += 1
                n = place.placement[min(b, len(place.placement) - 1)]
                t_comp = prof.block_ops_with_exit(b, prof.n_exits - 1) \
                    / nw.compute[n]
                st.energy_j += nw.power_active[n] * t_comp
                if b < last_block:
                    n2 = place.placement[min(b + 1, len(place.placement) - 1)]
                    if n2 != n:
                        st.energy_j += (nw.e_tx[n] + nw.e_rx[n2]) \
                            * prof.cut_bits[b]
            else:
                st.blocks_saved += 1

    def step(self) -> None:
        if self.paused:
            return                # parked until feasibility is restored
        self._maybe_refill()      # background contingency refill (off the
        #                           failover critical path)
        self._fill_slots()
        if not any(self.slots):
            return
        toks = np.zeros((self.B, 1), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            consumed = int(self._slot_len[i])
            if consumed < len(r.prompt):
                toks[i, 0] = r.prompt[consumed]
            else:
                toks[i, 0] = r.tokens[-1] if r.tokens else r.prompt[-1]

        logits, self.caches, exits = self._decode(
            self.params, self.caches, jnp.asarray(toks),
            jnp.int32(self.pos))
        self.pos += 1
        self.stats.steps += 1

        # gate every exit with the fused kernel; first-exit-wins
        confs, args = [], []
        for j, p_idx in enumerate(self.cfg.exit_layer_list):
            c, a = ee_gate(exits[f"exit_{p_idx}"])
            confs.append(np.asarray(c))
            args.append(np.asarray(a))
        c_f, a_f = ee_gate(logits)
        confs.append(np.asarray(c_f))
        args.append(np.asarray(a_f))

        for i, r in enumerate(self.slots):
            if r is None:
                continue
            self._slot_len[i] += 1
            if self._slot_len[i] < len(r.prompt):
                continue  # still consuming the prompt
            exit_idx = self.n_exits - 1
            for j in range(self.n_exits - 1):
                if confs[j][i] >= self.thresholds[j]:
                    exit_idx = j
                    break
            token = int(args[exit_idx][i])
            r.tokens.append(token)
            r.exits_taken.append(exit_idx)
            self.stats.tokens_out += 1
            self._charge(exit_idx)
            if len(r.tokens) >= r.max_new_tokens:
                r.done = True
                self.slots[i] = None   # continuous batching: free the slot


def serve_with_churn(engine: SplitServeEngine,
                     trace: Sequence[Sequence[ChurnEvent]], *,
                     steps_per_tick: int = 1,
                     uplink_bps: float = MOBILE_UPLINK_BPS
                     ) -> List[Dict[str, object]]:
    """Serve through a churn trace: per tick, apply the events
    (``engine.on_tick`` — re-splits, failovers, library refills) then run
    ``steps_per_tick`` decode steps (no-ops while the engine is paused).
    Returns the per-tick reports."""
    if steps_per_tick < 0:
        raise ValueError(f"steps_per_tick must be >= 0, got {steps_per_tick}")
    reports: List[Dict[str, object]] = []
    for events in trace:
        rep = engine.on_tick(events, uplink_bps=uplink_bps)
        for _ in range(steps_per_tick):
            engine.step()
        reports.append(rep)
    return reports
