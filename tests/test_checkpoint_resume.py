"""Crash-consistent serving: checkpoint/restore of the full population
state through ``ChurnOrchestrator``.

The oracle everywhere is bit-exactness: a run that is killed and resumed
from its newest checkpoint must produce the same TickReports (minus
wall-clock timing fields) and the same incumbent arrays as the same run
left uninterrupted — in plain, congestion-coupled, and contingency-armed
modes.  Crash points are driven deterministically by
``FaultPlan.crash_hook`` (the SIGKILL variant lives in
tests/test_faults_subprocess.py).
"""
import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.core.capacity import SharedCapacity
from repro.core.faults import FaultPlan, FaultSpec, InjectedCrash
from repro.core.online import ChurnOrchestrator, population_cohorts
from repro.runtime import checkpoint as ckpt

T, U, SEED = 12, 24, 7

#: wall-clock fields excluded from report comparison
TIMING = ("t_ingest_ms", "t_relax_ms", "t_post_ms", "t_reprice_ms")


def _trace():
    rng = np.random.default_rng(SEED)
    Q = 0.4 + 0.6 * rng.random((T, U))
    A = rng.integers(0, 3, size=(T, U))
    return Q, A


def build(mode="plain"):
    pops = population_cohorts(U, n_extra_edge=1, gamma=8)
    kw = {}
    if mode == "congestion":
        N = pops[0].N
        nc = np.full(N, np.inf)
        lc = np.full((N, N), np.inf)
        nc[2] = 120.0                    # one contended edge helper
        kw["shared_capacity"] = SharedCapacity(node_cap=nc, link_cap=lc)
    if mode == "contingency":
        kw["contingency"] = True
    return ChurnOrchestrator(population=pops, hysteresis=0.05, **kw)


def assert_reports_equal(a, b):
    assert len(a) == len(b), (len(a), len(b))
    for ra, rb in zip(a, b):
        da, db = dataclasses.asdict(ra), dataclasses.asdict(rb)
        for k in TIMING:
            da.pop(k), db.pop(k)
        assert da == db, (ra.tick,
                          {k: (da[k], db[k]) for k in da if da[k] != db[k]})


def snap_incumbents(o):
    return [(p.inc_found.copy(), p._inc_exit.copy(), p._inc_place.copy(),
             p._inc_energy.copy()) for p in o.pops]


def assert_inc_equal(sa, sb):
    for a, b in zip(sa, sb):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# kill-free oracle: save at boundaries, resume in a FRESH orchestrator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["plain", "congestion", "contingency"])
def test_resume_is_bit_identical(mode, tmp_path):
    Q, A = _trace()
    o1 = build(mode)
    r1 = o1.run_arrays(Q, A)

    d = str(tmp_path / "ck")
    o2 = build(mode)
    r2a = o2.run_arrays(Q[:7], A[:7], checkpoint_dir=d, checkpoint_every=4)
    o3 = build(mode)
    r2b = o3.resume(d, Q, A)            # restores trace_pos=7 (final save)
    assert len(r2a) + len(r2b) == T
    assert_reports_equal(r1, r2a + r2b)
    assert_inc_equal(snap_incumbents(o1), snap_incumbents(o3))


@pytest.mark.parametrize("mode", ["plain", "congestion", "contingency"])
def test_mid_boundary_restore(mode, tmp_path):
    Q, A = _trace()
    o1 = build(mode)
    r1 = o1.run_arrays(Q, A)

    d = str(tmp_path / "ck")
    build(mode).run_arrays(Q[:7], A[:7], checkpoint_dir=d,
                           checkpoint_every=4)
    steps = ckpt.available_steps(d)
    assert len(steps) >= 2              # boundary save + final save
    o4 = build(mode)
    pos = o4.restore(d, step=steps[0])
    assert pos == 4
    r3 = o4.run_arrays(Q[pos:], A[pos:], _trace_offset=pos)
    assert_reports_equal(r1[pos:], r3)
    assert_inc_equal(snap_incumbents(o1), snap_incumbents(o4))


def test_checkpoint_off_run_unchanged(tmp_path):
    Q, A = _trace()
    r_off = build().run_arrays(Q, A)
    r_on = build().run_arrays(Q, A, checkpoint_dir=str(tmp_path / "ck"),
                              checkpoint_every=5)
    assert_reports_equal(r_off, r_on)


def test_checkpoint_every_requires_dir():
    Q, A = _trace()
    with pytest.raises(ValueError, match="checkpoint_dir"):
        build().run_arrays(Q, A, checkpoint_every=3)


# ---------------------------------------------------------------------------
# injected crashes at every pipeline stage, then resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stage", ["ingest", "relax", "post"])
def test_crash_and_resume_every_stage(stage, tmp_path):
    Q, A = _trace()
    r_clean = build().run_arrays(Q, A)
    plan = FaultPlan(specs=[FaultSpec(kind="crash", tick=6, stage=stage)])
    d = str(tmp_path / "ck")
    o = build()
    with pytest.raises(InjectedCrash):
        o.run_arrays(Q, A, checkpoint_dir=d, checkpoint_every=3,
                     fault_plan=plan)
    o2 = build()
    tail = o2.resume(d, Q, A)           # plan not passed: crash cleared
    pos = T - len(tail)
    assert pos in (3, 6)                # last boundary before the crash
    assert_reports_equal(r_clean[pos:], tail)


def test_resume_rejects_short_trace(tmp_path):
    Q, A = _trace()
    d = str(tmp_path / "ck")
    build().run_arrays(Q, A, checkpoint_dir=d, checkpoint_every=4)
    with pytest.raises(ValueError, match="trace"):
        build().resume(d, Q[:3], A[:3])


# ---------------------------------------------------------------------------
# damage handling at the orchestrator level
# ---------------------------------------------------------------------------

def test_restore_skips_damaged_newest_step(tmp_path):
    Q, A = _trace()
    d = str(tmp_path / "ck")
    build().run_arrays(Q[:7], A[:7], checkpoint_dir=d, checkpoint_every=4)
    steps = ckpt.available_steps(d)
    assert len(steps) >= 2
    # truncate the newest checkpoint's array payload
    newest = pathlib.Path(d) / f"step_{steps[-1]:012d}" / ckpt.ARRAYS
    newest.write_bytes(newest.read_bytes()[:20])
    o = build()
    pos = o.restore(d)                  # falls back to the older step
    assert pos == 4
    r = o.run_arrays(Q[pos:], A[pos:], _trace_offset=pos)
    r_clean = build().run_arrays(Q, A)
    assert_reports_equal(r_clean[pos:], r)


def test_restore_rejects_wrong_population(tmp_path):
    Q, A = _trace()
    d = str(tmp_path / "ck")
    build().run_arrays(Q[:5], A[:5], checkpoint_dir=d, checkpoint_every=5)
    pops = population_cohorts(U - 4, n_extra_edge=1, gamma=8)
    o = ChurnOrchestrator(population=pops, hysteresis=0.05)
    with pytest.raises(ValueError, match="users"):
        o.restore(d)


def test_restore_rejects_congestion_mismatch(tmp_path):
    Q, A = _trace()
    d = str(tmp_path / "ck")
    build("congestion").run_arrays(Q[:5], A[:5], checkpoint_dir=d,
                                   checkpoint_every=5)
    with pytest.raises(ValueError, match="congestion"):
        build("plain").restore(d)


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        build().restore(str(tmp_path / "nothing"))


def test_checkpoint_extra_records_trace_position(tmp_path):
    Q, A = _trace()
    d = str(tmp_path / "ck")
    build().run_arrays(Q, A, checkpoint_dir=d, checkpoint_every=6)
    steps = ckpt.available_steps(d)
    for s in steps:
        man = json.loads((pathlib.Path(d) / f"step_{s:012d}" /
                          ckpt.MANIFEST).read_text())
        extra = man["extra"]
        assert extra["n_users"] == U
        assert extra["trace_pos"] in (6, T)
