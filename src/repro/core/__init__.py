"""Core: the paper's contribution — FIN placement of early-exit DNNs.

Public API:
  system_model   — tiers / nodes / per-app slices (Plane 1)
  dnn_profile    — block/exit profiles (Plane 2), paper Tables II-IV
  extended_graph — single-plane extended graph with Eq. (1)-(2) weights
  feasible_graph — gamma-replicated FIN feasibility graph (Eq. 4 + pruning)
  fin / mcp / optimum — the three solvers compared in Sec. V
  problem        — configuration evaluation against (3a)-(3e)
  multiapp       — Sec. V multi-application orchestration
  capacity       — population-shared node/link capacity + congestion pricing
  contingency    — precomputed-failover library (O(1) failure masks)
"""
from .system_model import (NodeSpec, Network, make_node, make_network,
                           PAPER_TIERS, TPU_TIERS)
from .dnn_profile import (DNNProfile, ExitSpec, paper_profile, all_paper_apps,
                          synthetic_profile, BITS_PER_FEATURE)
from .problem import (AppRequirements, Config, ConfigEval, Solution,
                      evaluate_config)
from .extended_graph import (ExtendedGraph, build_extended_graph,
                             build_extended_graphs, to_networkx)
from .feasible_graph import (FeasibleGraph, build_feasible_graph,
                             build_feasible_graphs)
from .fin import solve_fin, solve_many, fin_all_exit_costs
from .frontier import (FrontierRow, ParetoFrontier, brute_force_frontier,
                       frontier_from_rows, pareto_mask)
from .plan import (Plan, PlanStats, solve_plans, update_uplinks,
                   migration_delta)
from .mcp import solve_mcp
from .optimum import solve_opt
from .multiapp import (run_multiapp, MultiAppResult, AppStats, PlanCache,
                       PAPER_MULTIAPP_REQS, app_price_weights,
                       default_solvers, user_network, user_networks)
from .scenarios import ChurnEvent, churn_trace
from .population import Population, PopulationStats
from .capacity import (SharedCapacity, CongestionController,
                       CongestionReport, accumulate_loads, config_load_rows)
from .contingency import (ContingencyEntry, ContingencyLibrary,
                          ContingencyPolicy, ContingencyStats,
                          NoFeasiblePlacement, PopulationContingency,
                          candidate_masks, tier_groups_of)
from .online import (ChurnOrchestrator, ChurnStats, TickReport,
                     population_cohorts, population_plans)

__all__ = [
    "NodeSpec", "Network", "make_node", "make_network", "PAPER_TIERS",
    "TPU_TIERS", "DNNProfile", "ExitSpec", "paper_profile", "all_paper_apps",
    "synthetic_profile", "BITS_PER_FEATURE", "AppRequirements", "Config",
    "ConfigEval", "Solution", "evaluate_config", "ExtendedGraph",
    "build_extended_graph", "build_extended_graphs", "to_networkx",
    "FeasibleGraph", "build_feasible_graph", "build_feasible_graphs",
    "solve_fin", "solve_many", "fin_all_exit_costs",
    "FrontierRow", "ParetoFrontier", "brute_force_frontier",
    "frontier_from_rows", "pareto_mask",
    "Plan", "PlanStats", "solve_plans", "update_uplinks", "migration_delta",
    "solve_mcp",
    "solve_opt", "run_multiapp", "MultiAppResult", "AppStats",
    "PAPER_MULTIAPP_REQS", "default_solvers", "user_network",
    "user_networks", "PlanCache",
    "ChurnEvent", "churn_trace", "ChurnOrchestrator", "ChurnStats",
    "TickReport", "population_plans", "population_cohorts",
    "Population", "PopulationStats",
    "SharedCapacity", "CongestionController", "CongestionReport",
    "accumulate_loads", "config_load_rows", "app_price_weights",
    "ContingencyEntry", "ContingencyLibrary", "ContingencyPolicy",
    "ContingencyStats", "NoFeasiblePlacement", "PopulationContingency",
    "candidate_masks", "tier_groups_of",
]
