"""Struct-of-arrays population engine: whole-cohort churn ticks.

``plan.update_uplinks`` / ``plan.solve_plans`` batch the *math* of a churn
tick but keep the *state* in per-user ``Plan`` objects: every tick pays U
Python method calls, U small ``np.stack`` re-packings and U ``_apply_qpack``
scatter loops before any vectorized work starts — which is what caps the
PR-3 churn loop at ~1e4 user-ticks/s.  :class:`Population` inverts the
layout: one cohort of same-shape users (one network topology, one DNN
profile, one requirements triple, one solver parameterization) owns its
batched state as single contiguous arrays —

  * ``(U, N)`` per-user source-link bandwidth vectors,
  * ``(U, N)`` failure bitmaps,
  * ``(U, L)`` / ``(U,)`` incumbent placements, exits and energies,

and the per-tick pipeline — channel ingest -> fused requantize+signature
kernel -> in-cell cache check -> chained banded relaxation ->
argmin/post-pass — runs as whole-array operations with NO per-user Python
on the hot path.  Quantized uplink packs are NOT stored per user: a
user's pack always equals their cohort state's ``stq`` (states are keyed
BY the pack), so the engine keeps one int16 signature row per *state*
(``_stq_enc``) and stale-row requantization compares fresh signatures
against a gather from that table — the ``(U, M, 2L-1, N)`` float64 pack
array (7 GB at 1e7 users) is gone, and re-keying touches exactly the
rows whose encoding moved (``kernels/ee_gate/population.py`` holds the
fused quantize->int16->signature launch, numpy oracle + jitted jnp).

The DP layer exploits that quantization makes the relaxation tensors
piecewise-constant in the channel *across the cohort*, not just across
ticks: users whose quantized packs (and failure masks) coincide share one
*cohort state* — one (M, L-1, N, N) steepness stack, one relaxed DP grid,
one memoized per-exit minimum, one backtracked candidate list.  A tick
relaxes only the cohort states born this tick (chained float64 banded
relaxation, cache-residency chunked via ``bellman_ford.relax_chunk_rows``),
so a million AR(1)-fading users cost a few hundred relaxations, and the
exact per-user post-pass re-reads the *true* bandwidth through the shared
candidates (``fin._best_feasible`` with a per-state candidate cache).

Results are bit-exact vs per-user ``Plan.solve()`` (hence vs cold
``solve_fin``) on the float64 numpy backends: the ingest replicates the
packed requantizer of ``plan.update_uplinks`` elementwise, states
materialize through the same scatter formulas as ``Plan._apply_qpack``,
the relaxation and post-pass are the shared ``bellman_ford`` / ``fin``
code paths, and the rare no-feasible-path tighten loop falls back to a
fresh per-user ``Plan`` (whose warm==cold invariant is property-tested).
``backend="jnp"/"pallas"`` swap in the float32 engines; ``backend="mesh"``
routes the chained relaxation through the device-mesh execution layer
(``repro.sharding.population``), sharding the stacked (D, L-1, N, N)
relaxation over the user axis of a jax mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.kernels.ee_gate.population import QuantConsts, quant_signature

from .bellman_ford import (batched_banded_relax_argmin,
                           batched_banded_relax_minarg, relax_chunk_rows)
from .dnn_profile import DNNProfile
from .feasible_graph import build_feasible_graph
from .fin import (DP_BACKENDS, _BandedArgDP, _backtrack, _best_feasible,
                  _exit_dmin)
from .frontier import (ParetoFrontier, eval_config_users, frontier_from_rows,
                       scan_state_users)
from .plan import Plan, _validate_bps_values, _validate_population_bps
from .problem import AppRequirements, Config, ConfigEval, Solution
from .system_model import Network
from .tolerances import dist_tol

__all__ = ["Population", "PopulationStats", "TelemetryPolicy"]


@dataclass(frozen=True)
class TelemetryPolicy:
    """What :meth:`Population.ingest` does with corrupt channel readings.

    Without a policy the engine fails LOUDLY: NaN/Inf/negative bandwidth
    raises a ``ValueError`` naming the offending users — garbage must
    never silently key a shared cohort state.  With a policy the reading
    is absorbed instead:

    ``mode="clamp"``       bad *entries* are replaced by the user's
                           current stored value (entry-wise last known
                           good); the rest of the row ingests normally.
    ``mode="quarantine"``  a user with ANY bad entry (or a stuck sensor,
                           below) holds their entire last-known-good
                           uplink vector — they keep serving their
                           incumbent and rejoin automatically on the
                           first clean reading.  Per-tick transitions are
                           counted in ``PopulationStats.quarantines`` /
                           ``recoveries`` (the orchestrator surfaces them
                           on ``TickReport``).
    ``mode="raise"``       the loud default, as a policy object.

    ``stuck_window > 0`` adds frozen-sensor detection to the quarantine
    mode: a user whose raw reading row repeats EXACTLY for that many
    consecutive ingests is quarantined until the reading moves again.
    """

    mode: str = "quarantine"
    stuck_window: int = 0

    def __post_init__(self):
        if self.mode not in ("raise", "clamp", "quarantine"):
            raise ValueError(f"TelemetryPolicy.mode must be raise/clamp/"
                             f"quarantine, got {self.mode!r}")
        if self.stuck_window < 0:
            raise ValueError("TelemetryPolicy.stuck_window must be >= 0")


@dataclass
class PopulationStats:
    """Aggregate engine counters (diagnostics and benches)."""

    ingests: int = 0             # ingest calls
    uplink_updates: int = 0      # user-slots refreshed by ingest
    quant_changed: int = 0       # user-slots whose quantized pack moved
    dp_relaxes: int = 0          # cohort states relaxed
    dp_cache_hits: int = 0       # user-solves served from an existing state
    solves: int = 0              # user-solves issued
    unique_solves: int = 0       # distinct (state, bandwidth) groups solved
    fastpath_states: int = 0     # states served by the shared fast table
    fallbacks: int = 0           # per-user Plan fallbacks (tighten loop)
    state_evictions: int = 0     # cache compactions
    prebuilt_states: int = 0     # contingency states relaxed off-tick
    fused_relaxes: int = 0       # newborn batches relaxed in ONE launch
    chunked_relaxes: int = 0     # newborn batches split by the residency
    #                              budget (REPRO_RELAX_CHUNK_BYTES)
    bounded_relaxes: int = 0     # states relaxed from a parent's layer slice
    layers_skipped: int = 0      # relax layers skipped by bounded resumes
    mask_reuses: int = 0         # masked states served by a parent's grids
    telemetry_bad: int = 0       # corrupt (user, link) readings seen
    telemetry_clamped: int = 0   # entries clamped to last known good
    quarantines: int = 0         # users entering quarantine
    recoveries: int = 0          # users leaving quarantine
    # per-phase wall clock (accumulated only when the Population was built
    # with timing=True — the counters stay zero-cost when disabled)
    t_ingest_ms: float = 0.0     # channel ingest + requantize
    t_relax_ms: float = 0.0      # banded relaxation launches
    t_post_ms: float = 0.0       # exact post-pass (solve minus relax)
    # post-pass sub-breakdown (subsets of t_post_ms): the general stacked
    # candidate scans, the shared fast-table broadcasts, and the per-user
    # Plan fallbacks.  A fallback issued from inside a scan's no-feasible
    # branch counts in BOTH t_post_scan_ms and t_post_fallback_ms.
    t_post_scan_ms: float = 0.0
    t_post_fast_ms: float = 0.0
    t_post_fallback_ms: float = 0.0


def _group_runs(keys: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group equal keys: (uniq, first, order, bounds).

    ``order[bounds[g]:bounds[g + 1]]`` are the positions of group ``g``
    (first-occurrence-stable); ``first[g]`` is its first position.  One
    home for the unique/stable-argsort/searchsorted idiom the solve,
    incumbent-evaluation and frontier paths all share.

    All-equal keys short-circuit without sorting: a cold-start cohort (one
    bandwidth row tiled U times) and steady single-config ticks are the
    common case at scale, and one vectorized compare beats a million-row
    argsort by orders of magnitude.
    """
    n = len(keys)
    if n > 1 and bool((keys == keys[0]).all()):
        return (keys[:1], np.zeros(1, dtype=np.int64),
                np.arange(n, dtype=np.int64),
                np.array([0, n], dtype=np.int64))
    uniq, first, inv = np.unique(keys, return_index=True,
                                 return_inverse=True)
    order = np.argsort(inv, kind="stable")
    bounds = np.searchsorted(inv[order], np.arange(len(uniq) + 1))
    return uniq, first, order, bounds


def _enc_int16(q: np.ndarray) -> np.ndarray:
    """Checkpoint encoding of the inf-capable integral quantization arrays
    (qpack / state stq): values are either integers in [0, gamma] or +inf
    (gamma < int16 max is a ctor invariant), stored as int16 with -1 for
    inf — 4x smaller than float64 and exactly invertible."""
    e = np.empty(q.shape, dtype=np.int16)
    fin = np.isfinite(q)
    np.copyto(e, q, casting="unsafe", where=fin)
    e[~fin] = -1
    return e


def _dec_int16(e: np.ndarray) -> np.ndarray:
    out = e.astype(np.float64)
    out[e < 0] = np.inf
    return out


class _BwCols:
    """Column-gather view over selected rows of the bandwidth store.

    ``eval_config_users`` touches its bandwidth argument only through
    ``bwv[:, n]`` columns and ``len(bwv)``; gathering one (Us,) column per
    visited link — instead of materializing the whole (Us, N) row gather
    up front — keeps the per-group incumbent re-evaluation's memory
    traffic proportional to the links a configuration actually uses.
    Values are identical to ``bw[rows][:, n]``, so results stay bit-exact.
    """

    __slots__ = ("_bw", "_rows")

    def __init__(self, bw: np.ndarray, rows: np.ndarray):
        self._bw = bw
        self._rows = rows

    def __len__(self) -> int:
        return len(self._rows)

    def __getitem__(self, key) -> np.ndarray:
        s, n = key                       # only the bwv[:, n] access pattern
        assert s == slice(None)
        return self._bw[self._rows, n]


class _LazyBwCols:
    """Column view over the LAZY bandwidth store (see ``_bw_lazy``):
    column ``n`` materializes as ``scale * factors[:, n]`` on demand —
    per-element IEEE multiplies identical to the fused dense product's
    column — without ever writing the (U, N) product.  Supports only the
    ``bwv[:, n]`` / ``len(bwv)`` access pattern of ``eval_config_users``.
    """

    __slots__ = ("_sc", "_fac", "_src")

    def __init__(self, sc: np.ndarray, fac: np.ndarray, src: int):
        self._sc = sc
        self._fac = fac
        self._src = src

    def __len__(self) -> int:
        return len(self._sc)

    def __getitem__(self, key) -> np.ndarray:
        s, n = key
        assert s == slice(None)
        if n == self._src:
            return np.full(len(self._sc), np.inf)
        return self._sc * self._fac[:, n]


class _PendingSolve:
    """In-flight tick handle between ``solve_begin`` and ``solve_finish``:
    the begin-time (state, bandwidth) snapshot, the grouped rows and the
    relax future (None when the relaxation ran synchronously)."""

    __slots__ = ("users", "build_solutions", "t0", "sids", "first",
                 "order", "bounds", "bw", "future")

    def __init__(self, users: np.ndarray, build_solutions: bool,
                 t0: float):
        self.users = users
        self.build_solutions = build_solutions
        self.t0 = t0
        self.sids = None
        self.first = None
        self.order = None
        self.bounds = None
        self.bw = None
        self.future = None


class _CandCache:
    """Per-(mode, exit) energy-ordered candidate cache of a cohort state."""

    __slots__ = ("items", "order", "exhausted")

    def __init__(self):
        self.items: List[Tuple[Config, float]] = []
        self.order = None            # (flat argsort, values, n_finite)
        self.exhausted = False


class _FastTable:
    """The state's shared first-candidate frontier decision (vector path).

    Exact energies are bandwidth-independent, so the scalar post-pass's
    control flow over FIRST candidates — which (quantizer pass, exit)
    pairs get scanned, which exit wins, whether the ceil rescue replaces
    the main pass — is a pure function of the cohort state and is computed
    ONCE at state birth.  A tick then only has to check, per user, that
    every scanned first candidate is exactly feasible (stacked-array
    feasibility flags); when it is — the overwhelmingly common case — the
    cached choice broadcasts to every user of the state, and any state
    where it is not falls back to the general vectorized scan.

    ``scan``   [(mi, k, pos)] the shared flow evaluates, in order;
    ``keys``/``cfgs``  the distinct first-candidate configs (pos-indexed);
    ``choice`` (mi, k, pos, energy, e_comp, e_comm, used_ceil) or None
               (None = the tighten-fallback path).
    """

    __slots__ = ("keys", "cfgs", "scan", "choice")

    def __init__(self, keys, cfgs, scan, choice):
        self.keys = keys
        self.cfgs = cfgs
        self.scan = scan
        self.choice = choice


class _CohortState:
    """One unique (quantized pack, failure mask) DP state of the cohort.

    Everything hanging off the state is shared by every user currently in
    it: the masked steepness stack, the init grid, the relaxed DP grids
    (``dps``), the per-exit distance minima (memoized by ``fin._exit_dmin``
    on the dp objects), the backtracked candidate lists and the
    first-candidate fast table of the vectorized post-pass.
    """

    __slots__ = ("stq", "mask", "steep", "grid", "dps", "cand", "fast",
                 "parent")

    def __init__(self, stq: np.ndarray, mask: np.ndarray,
                 steep: np.ndarray, grid: np.ndarray, parent: int = -1):
        self.stq = stq               # (M, 2L-1, N)
        self.mask = mask             # (N,) bool
        self.steep = steep           # (M, L-1, N, N), masks applied
        self.grid = grid             # (M, N, G+1), masks applied
        self.dps: Optional[List[_BandedArgDP]] = None
        self.cand: Dict[Tuple[int, int], _CandCache] = {}
        self.fast: Optional[_FastTable] = None
        #: state id the first user keyed here came FROM — a bounded
        #: re-relaxation *hint* only: the resume path re-validates the
        #: layer-prefix equality against whatever state currently sits at
        #: this index (compaction may remap it), so a stale hint degrades
        #: to a full relax, never to a wrong result
        self.parent = parent


class _TightenResult:
    """Per-user outcome arrays of one batched tighten loop
    (``Population._tighten_batch``)."""

    __slots__ = ("found", "energy", "latency", "e_comp", "e_comm", "exit",
                 "rounds", "delta_eff", "cfgs")

    def __init__(self, n: int, max_tighten: int):
        self.found = np.zeros(n, dtype=bool)
        self.energy = np.full(n, np.inf)
        self.latency = np.zeros(n)
        self.e_comp = np.zeros(n)
        self.e_comm = np.zeros(n)
        self.exit = np.full(n, -1, dtype=np.int64)
        #: failed-round count == the succeeding round's index (Plan's
        #: ``meta["tighten_rounds"]``); max_tighten+1 when exhausted
        self.rounds = np.full(n, max_tighten + 1, dtype=np.int64)
        self.delta_eff = np.full(n, np.nan)
        self.cfgs: List[Optional[Config]] = [None] * n


class Population:
    """Struct-of-arrays engine for a cohort of same-shape users.

    One cohort shares (network topology, DNN profile, requirements, solver
    parameters); per-user state is the source-link bandwidth vector, the
    quantized uplink pack, the failure bitmap and the incumbent.  Mixed
    populations (several apps / topologies) are lists of cohorts — see
    ``online.population_cohorts``.

    ``backend``: ``minplus``/``banded`` (float64 numpy, bit-exact vs
    ``Plan.solve()``), ``jnp``/``pallas`` (float32 engines), ``mesh``
    (float32, sharded over the user axis of a jax device mesh).
    """

    def __init__(self, network: Network, profile: DNNProfile,
                 req: AppRequirements, n_users: int, *, gamma: int = 10,
                 lam: Optional[int] = None, quantize: str = "floor",
                 max_tighten: int = 6, tighten_factor: float = 0.85,
                 backend: str = "minplus", check_aggregate_load: bool = False,
                 user_ids: Optional[Sequence[int]] = None,
                 max_states: int = 65536, vector_postpass: bool = True,
                 bounded_rerelax: bool = True, timing: bool = False,
                 telemetry: Optional[TelemetryPolicy] = None,
                 fused_ingest: str = "numpy"):
        if n_users <= 0:
            raise ValueError(f"n_users must be positive, got {n_users}")
        if backend != "mesh" and DP_BACKENDS.get(backend) is None:
            raise ValueError(f"unknown Population backend {backend!r} "
                             f"(expected mesh or one of "
                             f"{sorted(DP_BACKENDS)})")
        if backend in ("numpy", "dense"):
            raise ValueError("Population requires a banded engine; the "
                             "dense backends exist for equivalence testing "
                             "only (use minplus/banded/jnp/pallas/mesh)")
        if gamma >= np.iinfo(np.int16).max:
            raise ValueError(f"gamma {gamma} overflows the int16 state "
                             f"encoding")
        if fused_ingest not in ("numpy", "jnp"):
            raise ValueError(f"unknown fused_ingest backend "
                             f"{fused_ingest!r} (expected numpy or jnp)")
        self.backend = backend
        #: backend of the rare per-user Plan fallback (same engine family)
        self._plan_backend = "jnp" if backend == "mesh" else backend
        self._engine = DP_BACKENDS[self._plan_backend]
        self._dist_tol = dist_tol(self._engine)

        # the prototype Plan owns every *shared* stage-1/2 tensor: the
        # pristine extended graph, the packed-requantizer constants and the
        # base quantized steepness stack that per-user states scatter their
        # source-node rows/cols into.  Building it through Plan (rather
        # than duplicating the builders) is what makes population state
        # equal per-plan state by construction.
        self._proto = Plan(network, profile, req, gamma=gamma, lam=lam,
                           quantize=quantize, max_tighten=max_tighten,
                           tighten_factor=tighten_factor, n_best=1,
                           backend=self._plan_backend,
                           check_aggregate_load=check_aggregate_load)
        self.profile = profile
        self.req = req
        self.gamma = gamma
        self.lam = self._proto.lam
        self.quantize = quantize
        self.max_tighten = max_tighten
        self.tighten_factor = tighten_factor
        self.check_aggregate_load = check_aggregate_load
        self.network0 = self._proto.network      # pristine base (live view)
        self.max_states = max_states

        N = self.network0.n_nodes
        L = profile.n_blocks
        self.U = int(n_users)
        self.N, self.L = N, L
        self.M = len(self._proto._modes)
        self.src = self.network0.source_node
        self.user_ids = (np.arange(self.U, dtype=np.int64)
                         if user_ids is None
                         else np.asarray(user_ids, dtype=np.int64))
        assert len(self.user_ids) == self.U

        # per-user SoA state (quantized packs live on the cohort states —
        # a user's pack IS their state's ``stq``, see the module doc)
        base_row = self._proto._bw[self.src].copy()
        base_row[self.src] = np.inf
        self._bw_vec = np.tile(base_row, (self.U, 1))          # (U, N)
        #: lazy bandwidth store: when set to (scale, factors) the DENSE
        #: ``_bw_vec`` contents are stale and the true store is the
        #: deferred product ``scale[:, None] * factors`` (src column inf).
        #: The dense-tick gate reads columns and the resolve subset reads
        #: rows, so the full (U, N) multiply — the single biggest memory
        #: pass of a steady tick — only happens if a dense consumer
        #: (checkpoint, partial ingest, slice reprice) actually shows up.
        #: All accessors (``_bw_dense``/``_bw_rows``/``_bw_cols``) produce
        #: values bit-identical to the eager multiply.
        self._bw_lazy: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._masked = np.zeros((self.U, N), dtype=bool)
        self._stale = np.zeros(self.U, dtype=bool)   # deferred requants
        self._user_state = np.full(self.U, -1, dtype=np.int64)
        self._solved = np.zeros(self.U, dtype=bool)
        self._inc_place = np.full((self.U, L), -1, dtype=np.int32)
        self._inc_exit = np.full(self.U, -1, dtype=np.int32)
        self._inc_energy = np.full(self.U, np.inf)
        self._solutions = np.full(self.U, None, dtype=object)
        #: whether any Solution object is live (lets the incumbent-only
        #: recording path skip the (U,) object-array clear entirely)
        self._any_solutions = False
        #: uniform-incumbent flag: the (exit, placement) every user is
        #: solved with, or None when unknown/mixed — lets the dense
        #: hysteresis gate skip the per-tick grouping key build
        self._inc_single: Optional[Tuple] = None

        # telemetry sanitization (see :class:`TelemetryPolicy`): quarantine
        # flags and frozen-sensor counters are always allocated (cheap);
        # the raw-reading history only when stuck detection is on
        self._telemetry = telemetry
        self._quarantined = np.zeros(self.U, dtype=bool)
        self._stuck_count = np.zeros(self.U, dtype=np.int32)
        self._last_raw = (np.full((self.U, N), np.nan)
                          if telemetry is not None
                          and telemetry.stuck_window > 0 else None)
        #: internal re-ingests (``update_slice`` replaying the stored
        #: bandwidths) must not look like telemetry ticks
        self._suspend_telemetry = False

        # cohort-state table (the cross-user DP dedupe)
        self._states: List[_CohortState] = []
        self._state_ids: Dict[bytes, int] = {}
        #: contingency-prebuilt state ids pinned through compaction
        #: (``core/contingency.py``; cleared when the state table is)
        self._pinned: set = set()
        #: cohort-wide exact-energy memo (energy is bandwidth-independent):
        #: (exit, placement) -> (energy, e_comp, e_comm); cleared with the
        #: state table on compute-slice churn
        self._cfg_energy: Dict[Tuple, Tuple[float, float, float]] = {}
        self._mesh_relaxer = None
        self._fallback_plan: Optional[Plan] = None
        #: vectorized frontier post-pass (core/frontier.py): all (candidate,
        #: user) pairs of a cohort state scored as stacked arrays instead of
        #: one scalar ``_best_feasible`` per unique (state, bandwidth) —
        #: bit-exact either way; False keeps the scalar path (the oracle).
        self._vector_postpass = bool(vector_postpass)
        #: bounded re-relaxation (affected-layer-onward resumes and whole-
        #: grid reuse for masked-out unreached nodes); False forces every
        #: newborn state through the full layer chain — the oracle switch
        #: the equivalence tests and benches flip
        self._bounded = bool(bounded_rerelax)
        #: live masked-entry count — lets the hot incumbent gate skip the
        #: (U, N) bitmap scan entirely when no user has a failure
        self._mask_count = 0
        self._timing = bool(timing)
        self._relax_executor = None      # lazy 1-thread pool (streaming)
        #: wall seconds of the most recent relaxation launch — the
        #: streaming pipeline's adaptive-overlap signal (see
        #: ``online.run_arrays``); always recorded, timing flag or not
        self._last_relax_s = 0.0
        self._ingest_backend = fused_ingest
        self._quant_consts: Optional[QuantConsts] = None
        #: tighten-cell dedupe for the batched fallback (see
        #: ``_tighten_batch``): relaxed single-mode states keyed by
        #: (round, signature@delta_eff, mask) plus the per-round base
        #: steepness stack.  Marginal users drift within a handful of
        #: quantization cells, so steady-state ticks hit these caches and
        #: the whole tighten herd costs scans, not relaxations.
        self._tighten_cache: Dict[Tuple[int, bytes, bytes],
                                  _CohortState] = {}
        self._tighten_base: Dict[int, np.ndarray] = {}
        self.stats = PopulationStats()
        # uniform cold start: every user holds the proto pack and an empty
        # failure mask, which is ONE cohort state — register it directly
        # instead of encoding/hashing U identical signature rows (the 1e7
        # cold start used to spend ~50 s here)
        self._enc_w = self.M * (2 * L - 1) * N
        self._stq_enc = np.empty((0, self._enc_w), dtype=np.int16)
        stq0 = self._proto._qpack.copy()
        mask0 = np.zeros(N, dtype=bool)
        self._user_state[:] = self._add_state(self._state_key(stq0, mask0),
                                              stq0, mask0)

    # ------------------------------------------------------------ properties
    @property
    def n_users(self) -> int:
        return self.U

    @property
    def n_states(self) -> int:
        return len(self._states)

    @property
    def depth_window_lo(self) -> Optional[int]:
        return self.gamma - self.lam if self.lam < self.gamma else None

    @property
    def masked_nodes(self) -> List[int]:
        """Nodes masked for EVERY user (the cohort-wide failure set)."""
        return [int(n) for n in np.nonzero(self._masked.all(axis=0))[0]]

    @property
    def inc_found(self) -> np.ndarray:
        """(U,) bool — users whose incumbent is a feasible configuration
        (``_best_feasible`` only ever returns exactly-feasible configs, so
        found == feasible, mirroring ``Solution.feasible``)."""
        return self._inc_exit >= 0

    def solution(self, u: int) -> Optional[Solution]:
        return self._solutions[u]

    def solutions(self, users: Optional[Sequence[int]] = None
                  ) -> List[Optional[Solution]]:
        users = range(self.U) if users is None else users
        return [self._solutions[int(u)] for u in users]

    # --------------------------------------------------------------- ingest
    def ingest(self, bps: Union[float, np.ndarray],
               users: Optional[np.ndarray] = None,
               requant: bool = True) -> Optional[np.ndarray]:
        """Per-tick channel ingest: set the selected users' source-link
        bandwidths and requantize their packs as ONE stacked pipeline.

        ``bps`` is a scalar, a (Us,) per-user scalar or a (Us, N)
        per-target matrix (``users`` defaults to the whole cohort).
        Elementwise identical to ``Plan.update_uplink`` per user; returns
        the (Us,) DP-input-changed flags.  Malformed shapes raise a clear
        ``ValueError`` up front (see ``plan._validate_population_bps``).

        ``requant=False`` defers the requantization: the bandwidths land
        now (incumbent re-evaluation reads only the TRUE bandwidth), the
        packs refresh lazily when a user actually re-solves — under
        hysteresis almost no one does, so the scale path skips ~all of the
        quantization work without changing any decision or solution.
        Returns None in that case (the change flags are not yet known).
        """
        t0 = time.perf_counter() if self._timing else 0.0
        users = (np.arange(self.U) if users is None
                 else np.asarray(users, dtype=np.int64))
        Us = len(users)
        self._bw_dense()      # partial write + last-known-good reads below
        arr = _validate_population_bps(bps, Us, self.N)
        vec = np.empty((Us, self.N))
        vec[:] = arr if arr.ndim == 2 else \
            (np.broadcast_to(np.asarray(arr, dtype=np.float64)
                             .reshape(-1, 1), (Us, self.N)))
        vec[:, self.src] = np.inf                # self-loop (Sec. II-A)
        if not self._suspend_telemetry:
            self._screen_rows(users, vec)
        self._bw_vec[users] = vec
        self.stats.ingests += 1
        self.stats.uplink_updates += Us
        if not requant:
            self._stale[users] = True
            if self._timing:
                self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3
            return None
        changed = self._requant_users(users, vec)
        self._stale[users] = False
        if self._timing:
            self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3
        return changed

    def ingest_factors(self, scale: np.ndarray, factors: np.ndarray,
                       requant: bool = True) -> Optional[np.ndarray]:
        """Whole-cohort ingest from a per-user scale and a per-user factor
        row: the new bandwidth matrix is ``scale[:, None] * factors``
        written straight into the SoA store (one fused multiply, no
        intermediate (U, N) staging copy).  ``factors`` encodes the static
        per-user link pattern (attachment edge, detach fraction) so a
        dense channel tick only has to supply the (U,) fading scale.

        Semantically identical to ``ingest(scale[:, None] * factors)``
        over all users; same ``requant`` contract.
        """
        if scale.shape != (self.U,) or factors.shape != (self.U, self.N):
            raise ValueError(
                f"ingest_factors expects scale ({self.U},) and factors "
                f"({self.U}, {self.N}); got {scale.shape} and "
                f"{factors.shape}")
        t0 = time.perf_counter() if self._timing else 0.0
        if self._telemetry is None or self._telemetry.mode == "raise":
            # loud default: a corrupt fading scale must not reach the store
            # (factors are orchestrator-owned link patterns, not telemetry)
            _validate_bps_values(scale, what="ingest_factors scale")
            if not requant:
                # defer the (U, N) product: the gate and resolve subset
                # read through the lazy accessors (see ``_bw_lazy``)
                self._bw_lazy = (scale, factors)
            else:
                np.multiply(scale[:, None], factors, out=self._bw_vec)
                self._bw_vec[:, self.src] = np.inf   # self-loop (Sec. II-A)
                self._bw_lazy = None
        else:
            # screened path: stage the product so quarantined/clamped rows
            # can be substituted before they land in the store — values are
            # bit-identical to the fused multiply
            self._bw_dense()       # substitution reads last-known-good rows
            vec = scale[:, None] * factors
            vec[:, self.src] = np.inf
            self._screen_rows(np.arange(self.U), vec)
            self._bw_vec[:] = vec
        self.stats.ingests += 1
        self.stats.uplink_updates += self.U
        if not requant:
            self._stale[:] = True
            if self._timing:
                self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3
            return None
        changed = self._requant_users(np.arange(self.U), self._bw_vec)
        self._stale[:] = False
        if self._timing:
            self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3
        return changed

    def _screen_rows(self, users: np.ndarray, vec: np.ndarray) -> None:
        """Telemetry screening over a staging ingest batch (in place).

        ``vec`` is the (Us, N) staging matrix about to be written into the
        bandwidth store (src column already inf).  Corrupt entries are
        NaN/Inf/negative outside the src column.  Without a policy (or in
        ``raise`` mode) any corruption raises a ``ValueError`` naming the
        offending users; ``clamp`` substitutes bad entries with the user's
        stored value; ``quarantine`` substitutes the WHOLE row of any
        offender (incl. stuck sensors) with their stored last-known-good
        vector — the subsequent wholesale store + requantize then treats a
        quarantined user exactly like a user whose channel froze, so no
        cohort state is ever keyed on a corrupt pack and held users keep
        serving their incumbent bit-exactly.
        """
        pol = self._telemetry
        bad_ent = ~np.isfinite(vec) | (vec < 0)
        bad_ent[:, self.src] = False
        any_bad = bool(bad_ent.any())
        if any_bad:
            self.stats.telemetry_bad += int(np.count_nonzero(bad_ent))
        if pol is None or pol.mode == "raise":
            if any_bad:
                _validate_bps_values(None, bad=bad_ent, users=users,
                                     what="ingest bps")
            return
        if pol.mode == "clamp":
            if any_bad:
                np.copyto(vec, self._bw_vec[users], where=bad_ent)
                self.stats.telemetry_clamped += \
                    int(np.count_nonzero(bad_ent))
            return
        # quarantine: row-level hold on corrupt or frozen readings
        bad_user = bad_ent.any(axis=1)
        if pol.stuck_window > 0:
            rep = (vec == self._last_raw[users]).all(axis=1)
            cnt = np.where(rep, self._stuck_count[users] + 1, 0)
            self._stuck_count[users] = cnt
            self._last_raw[users] = vec
            bad_user |= cnt >= pol.stuck_window
        was_q = self._quarantined[users]
        newly = bad_user & ~was_q
        healed = was_q & ~bad_user
        if newly.any():
            self._quarantined[users[newly]] = True
            self.stats.quarantines += int(np.count_nonzero(newly))
        if healed.any():
            self._quarantined[users[healed]] = False
            self.stats.recoveries += int(np.count_nonzero(healed))
        if bad_user.any():
            np.copyto(vec, self._bw_vec[users], where=bad_user[:, None])

    # ---------------------------------------------- lazy bandwidth accessors
    def _bw_dense(self) -> np.ndarray:
        """The dense (U, N) bandwidth store, materializing a pending lazy
        product first (one fused multiply — identical to the eager path)."""
        lz = self._bw_lazy
        if lz is not None:
            sc, fac = lz
            np.multiply(sc[:, None], fac, out=self._bw_vec)
            self._bw_vec[:, self.src] = np.inf
            self._bw_lazy = None
        return self._bw_vec

    def _bw_rows(self, users: np.ndarray) -> np.ndarray:
        """Selected users' bandwidth rows — a gather-then-multiply under a
        pending lazy store (per-element IEEE ops identical to multiplying
        first and gathering after), a plain row gather otherwise."""
        lz = self._bw_lazy
        if lz is None:
            return self._bw_vec[users]
        sc, fac = lz
        out = sc[users][:, None] * fac[users]
        out[:, self.src] = np.inf
        return out

    def _bw_cols(self):
        """Whole-store column view for ``eval_config_users`` (it touches
        only ``bwv[:, n]`` / ``len``): the dense array, or a zero-copy
        column materializer over the lazy (scale, factors) pair."""
        lz = self._bw_lazy
        if lz is None:
            return self._bw_vec
        return _LazyBwCols(lz[0], lz[1], self.src)

    def _refresh_states(self, users: np.ndarray) -> None:
        """Flush deferred requantizations (lazy ingest) for these users."""
        sel = users[self._stale[users]]
        if len(sel):
            t0 = time.perf_counter() if self._timing else 0.0
            self._requant_users(sel, self._bw_rows(sel))
            self._stale[sel] = False
            if self._timing:
                self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3

    def _quant(self) -> QuantConsts:
        """The fused requantizer's constants bundle — snapshots the proto
        packs, so compute-slice repricings must drop it (they rebuild the
        packs); backhaul repricings are bandwidth-only and keep it."""
        c = self._quant_consts
        if c is None:
            p = self._proto
            c = self._quant_consts = QuantConsts(
                bits_pack=p._bits_pack, C_pack=p._C_pack,
                mask_pack=p._mask_pack, load_pack=p._load_pack,
                modes=tuple(p._modes), gamma=self.gamma,
                delta=self.req.delta)
        return c

    def _requant_users(self, users: np.ndarray,
                       vec: np.ndarray) -> np.ndarray:
        """Fused requantize of the given users' bandwidth rows: ONE
        quantize->int16->signature launch (``kernels/ee_gate/population``,
        elementwise identical to ``plan.update_uplinks`` + the signature
        encode), compared against a gather from the per-state signature
        table — users whose encoding moved re-key through
        ``_assign_states`` with the fresh rows, everyone else costs one
        int16 row compare."""
        enc = quant_signature(vec, self._quant(),
                              backend=self._ingest_backend)
        old = self._stq_enc[self._user_state[users]]
        changed = (enc != old).any(axis=1)
        if changed.any():
            self._assign_states(users[changed], enc=enc[changed])
        self.stats.quant_changed += int(np.count_nonzero(changed))
        return changed

    # ------------------------------------------------------------- failures
    def mask_node(self, n: int, users: Optional[Sequence[int]] = None
                  ) -> "Population":
        """Node failure for ``users`` (default: the whole cohort) — same
        semantics as ``Plan.mask_node`` per user."""
        if n == self.src:
            raise ValueError("cannot mask the source-hosting node")
        sel = (np.arange(self.U) if users is None
               else np.asarray(users, dtype=np.int64))
        flip = sel[~self._masked[sel, n]]
        if len(flip):
            self._masked[flip, n] = True
            self._mask_count += len(flip)
            self._assign_states(flip)
        return self

    def unmask_node(self, n: int, users: Optional[Sequence[int]] = None
                    ) -> "Population":
        sel = (np.arange(self.U) if users is None
               else np.asarray(users, dtype=np.int64))
        flip = sel[self._masked[sel, n]]
        if len(flip):
            self._masked[flip, n] = False
            self._mask_count -= len(flip)
            self._assign_states(flip)
        return self

    def update_slice(self, frac: Union[float, np.ndarray]) -> "Population":
        """Cohort-wide compute-slice rescale (``Plan.update_slice`` with
        ``nodes=None`` for every user).  ``frac`` is a scalar or an (N,)
        per-node factor vector (congestion pricing rescales individual
        nodes); either way it applies to every user of the cohort —
        per-user slices would break the cohort's shared energy tensors,
        so model those as separate cohorts.
        """
        self._proto.update_slice(frac)
        t0 = time.perf_counter() if self._timing else 0.0
        # the proto rebuilt its packs and base tensors in place or replaced
        # them; every cached cohort state quantized against the old compute
        # terms is now stale (incl. fast tables), the memoized exact
        # energies moved with the compute terms, and the fallback plan's
        # compute base as well.  Capture the pre-slice signatures first —
        # the quant_changed counter compares against them, and the table
        # (their backing store) is about to clear.
        old_enc = self._stq_enc[self._user_state]
        self._states = []
        self._state_ids = {}
        self._pinned = set()
        self._cfg_energy = {}
        self._fallback_plan = None
        self._quant_consts = None
        self._tighten_cache = {}
        self._tighten_base = {}
        self._stq_enc = np.empty((0, self._enc_w), dtype=np.int16)
        # requantize every user against the new compute terms in one fused
        # launch and re-key everyone — the stored bandwidths were already
        # screened, so this must not look like a telemetry tick
        # (quarantine/stuck state and counters stay untouched)
        enc = quant_signature(self._bw_dense(), self._quant(),
                              backend=self._ingest_backend)
        self.stats.ingests += 1
        self.stats.uplink_updates += self.U
        self.stats.quant_changed += \
            int(np.count_nonzero((enc != old_enc).any(axis=1)))
        self._assign_states(np.arange(self.U), enc=enc)
        self._stale[:] = False
        if self._timing:
            self.stats.t_ingest_ms += (time.perf_counter() - t0) * 1e3
        return self

    def update_backhaul(self, scale: Union[float, np.ndarray]
                        ) -> "Population":
        """Cohort-wide backhaul rescale (``Plan.update_backhaul`` for every
        user): non-source links serve ``bw_base * scale`` — the congestion
        pricing delta for shared links.

        The packed uplink requantizer constants are bandwidth-independent,
        so every user's quantized pack keeps its value verbatim — and
        therefore so does the whole (pack, mask) partition: the cohort
        states are rebuilt IN PLACE (fresh steepness/init tensors against
        the repriced base; DP grids, candidate caches and fast tables
        dropped) with their ids, signature keys, user assignment and
        pinned set all preserved.  No per-user pass at all — link
        repricing costs O(states), not O(users), which is what keeps the
        congestion fixed-point loop cheap at population scale.  The
        memoized exact energies survive too — Eq. (2) has no bandwidth
        term.
        """
        self._proto.update_backhaul(scale)
        for s in self._states:
            s.steep, s.grid = self._state_tensors(s.stq, s.mask)
            s.dps = None
            s.cand = {}
            s.fast = None
        self._fallback_plan = None
        # tighten states quantize the repriced non-source links too
        self._tighten_cache = {}
        self._tighten_base = {}
        return self

    # ------------------------------------------------------- state registry
    def _assign_states(self, users: np.ndarray,
                       enc: Optional[np.ndarray] = None) -> None:
        """(Re)key the given users' (quantized pack, mask) signatures into
        cohort states, materializing states never seen before — touching
        ONLY the given rows and merging into the existing table (the
        stale-subset re-key; callers pass exactly the users whose
        signature may have moved).

        ``enc`` is the users' freshly-quantized (Us, M*K2*N) int16 pack
        encoding (the fused ingest kernel's output); None re-keys the
        users' CURRENT packs (mask flips), read back from the per-state
        signature table — per-user packs are never stored, a user's pack
        always equals their state's."""
        Us = len(users)
        if Us == 0:
            return
        old_sids = self._user_state[users]       # bounded-resume hints
        if enc is None:
            enc = self._stq_enc[old_sids]
        W = self._enc_w
        rows = np.empty((Us, W + self.N), dtype=np.int16)
        rows[:, :W] = enc
        rows[:, W:] = self._masked[users]
        v = rows.view(np.dtype((np.void, rows.shape[1] * 2))).ravel()
        K2 = 2 * self.L - 1

        def materialize(j: int) -> int:
            key = v[j].tobytes()
            sid = self._state_ids.get(key)
            if sid is None:
                stq = _dec_int16(enc[j]).reshape(self.M, K2, self.N)
                sid = self._add_state(key, stq,
                                      self._masked[int(users[j])].copy(),
                                      parent=int(old_sids[j]))
            return sid

        if Us > 1 and bool((v == v[0]).all()):
            # one signature for the whole batch (cold start, uniform
            # scale moves): skip the million-row unique/argsort entirely
            self._user_state[users] = materialize(0)
            if len(self._states) > self.max_states:
                self._compact_states()
            return
        uniq, first, inv = np.unique(v, return_index=True,
                                     return_inverse=True)
        sids = np.empty(len(uniq), dtype=np.int64)
        for i, j in enumerate(first):
            sids[i] = materialize(int(j))
        self._user_state[users] = sids[inv]
        if len(self._states) > self.max_states:
            self._compact_states()

    def _state_key(self, stq: np.ndarray, mask: np.ndarray) -> bytes:
        """The scalar form of ``_assign_states``'s signature encoding —
        byte-identical to the batched path, so an out-of-band caller (the
        contingency prebuilder) can probe/register states a user would be
        keyed into without a user actually holding that (pack, mask)."""
        M, K2, N = self.M, 2 * self.L - 1, self.N
        enc = np.empty(M * K2 * N + N, dtype=np.int16)
        q = np.ascontiguousarray(stq).reshape(-1)
        fin = np.isfinite(q)
        np.copyto(enc[:M * K2 * N], q, casting="unsafe", where=fin)
        enc[:M * K2 * N][~fin] = -1
        enc[M * K2 * N:] = mask
        return enc.tobytes()

    def _state_tensors(self, stq: np.ndarray, mask: np.ndarray,
                       base_steep: Optional[np.ndarray] = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """A state's DP input tensors: scatter the pack's source-node
        rows/cols into a copy of the base steepness stack and rebuild the
        init grid — the exact formulas of ``Plan._apply_qpack``, with
        ``Plan._quant_state``'s failure masking folded in.  (Also the
        backhaul-repricing rebuild: the base stack moved, the pack did
        not.)  ``base_steep`` swaps in a different-width base — the
        tighten fallback passes a single-mode delta_eff stack whose pack
        carries only the main quantizer."""
        proto = self._proto
        L, G, src = self.L, self.gamma, self.src
        steep = (proto._steep if base_steep is None
                 else base_steep).copy()             # (M, L-1, N, N) base
        steep[:, :, src, :] = stq[:, :L - 1]
        steep[:, :, :, src] = stq[:, L:]
        grid = np.full((stq.shape[0], self.N, G + 1), np.inf)
        d = stq[:, L - 1, :]                         # (M, N) init depths
        mi_i, n_i = np.nonzero(np.isfinite(d) & (d <= G))
        grid[mi_i, n_i, d[mi_i, n_i].astype(np.int64)] = \
            proto._ext.init_E[n_i]
        if mask.any():
            steep[:, :, mask, :] = np.inf
            steep[:, :, :, mask] = np.inf
            grid[:, mask, :] = np.inf
        return steep, grid

    def _enc_push(self, enc_row: np.ndarray) -> None:
        """Append a state's int16 signature row to the amortized-growing
        ``_stq_enc`` table (valid rows = ``len(self._states)``)."""
        n = len(self._states)
        cap = len(self._stq_enc)
        if n > cap:
            grown = np.empty((max(16, 2 * cap, n), self._enc_w),
                             dtype=np.int16)
            grown[:cap] = self._stq_enc
            self._stq_enc = grown
        self._stq_enc[n - 1] = enc_row

    def _add_state(self, key: bytes, stq: np.ndarray,
                   mask: np.ndarray, parent: int = -1) -> int:
        """Materialize a cohort state (see ``_state_tensors``) and record
        its int16 signature row."""
        steep, grid = self._state_tensors(stq, mask)
        sid = len(self._states)
        self._states.append(_CohortState(stq, mask, steep, grid,
                                         parent=parent))
        self._state_ids[key] = sid
        self._enc_push(_enc_int16(stq).reshape(-1))
        return sid

    def _compact_states(self) -> None:
        """Drop cohort states no user references (bounds cache growth under
        adversarial churn; referenced states and their DP grids survive).
        Contingency-pinned states survive too — evicting a prebuilt state
        would silently turn its failover back into a relaxation."""
        live = np.unique(self._user_state)
        if self._pinned:
            live = np.unique(np.concatenate(
                [live, np.fromiter(self._pinned, dtype=np.int64)]))
        remap = {int(s): i for i, s in enumerate(live)}
        self._states = [self._states[int(s)] for s in live]
        self._stq_enc = self._stq_enc[live]
        self._state_ids = {k: remap[s] for k, s in self._state_ids.items()
                           if s in remap}
        self._user_state = np.searchsorted(live, self._user_state)
        self._pinned = {remap[s] for s in self._pinned if s in remap}
        self.stats.state_evictions += 1

    # ------------------------------------------------------------ relaxation
    def _relax_states(self, sids: Sequence[int], *,
                      prebuilt: bool = False) -> None:
        """Chained banded relaxation of the given (unrelaxed) cohort states.

        Newborns split three ways: states whose validated parent hint
        proves a layer-prefix match resume from the parent's saved grid
        slice (bounded re-relaxation); pure-mask deltas on nodes the
        parent never reached share the parent's relaxed grids outright;
        the rest ride the full chain — ONE fused launch when the whole
        stack fits the cache-residency budget
        (``bellman_ford.relax_chunk_rows``), the chunked fallback when it
        does not.  ``prebuilt`` routes the counter to
        ``stats.prebuilt_states`` (contingency refills relax off the
        failure tick; a covered tick's ``dp_relaxes`` delta stays zero)."""
        states = [self._states[int(s)] for s in sids]
        if not states:
            return
        t0 = time.perf_counter()
        full: List[_CohortState] = []
        resume: Dict[int, List[Tuple[_CohortState, _CohortState]]] = {}
        if self._bounded:
            for s in states:
                hint = self._resume_hint(s)
                if hint is None:
                    full.append(s)
                    continue
                kind, parent, l0 = hint
                if kind == "share":
                    s.dps = [_BandedArgDP(pd.hist, pd.par_n, s.steep[mi])
                             for mi, pd in enumerate(parent.dps)]
                    self.stats.mask_reuses += 1
                else:
                    resume.setdefault(l0, []).append((s, parent))
        else:
            full = states
        if full:
            self._relax_full(full)
        for l0 in sorted(resume):
            pairs = resume[l0]
            self._relax_resume(l0, pairs)
            self.stats.bounded_relaxes += len(pairs)
            self.stats.layers_skipped += l0 * len(pairs)
        if prebuilt:
            self.stats.prebuilt_states += len(states)
        else:
            self.stats.dp_relaxes += len(states)
        self._last_relax_s = time.perf_counter() - t0
        if self._timing:
            self.stats.t_relax_ms += self._last_relax_s * 1e3

    def _resume_hint(self, s: _CohortState
                     ) -> Optional[Tuple[str, _CohortState, int]]:
        """Validate a newborn's parent hint (see ``_CohortState.parent``).

        Returns None (full relax), ("share", parent, 0) when the parent's
        relaxed grids serve the state verbatim — a pure mask-add delta on
        nodes the parent's chain never reached (all-inf rows at every
        block, so no finite cell and no backtrack can touch them) — or
        ("resume", parent, l0) when layers < l0 are provably identical.
        The hint is re-validated against whatever state sits at the index
        NOW, so compaction/renumbering can only cost speed, not
        correctness; resumes are float64-engine-only (the f32 engines
        round intermediates in-chain, so a spliced prefix is not an
        identity there)."""
        p = s.parent
        if p < 0 or p >= len(self._states):
            return None
        parent = self._states[p]
        if parent is s or parent.dps is None:
            return None
        L = self.L
        if np.array_equal(s.stq, parent.stq):
            added = s.mask & ~parent.mask
            if not added.any() or (parent.mask & ~s.mask).any():
                return None
            for pd in parent.dps:
                if np.isfinite(pd.hist[:, added, :]).any():
                    return None
            return ("share", parent, 0)
        if self._engine != "banded" or self.backend == "mesh":
            return None
        if not np.array_equal(s.mask, parent.mask):
            return None
        # first affected relax layer: pack row r < L-1 scatters into the
        # layer-r source row, row r >= L into the layer-(r-L) source col;
        # a moved init-depth row (r == L-1) moves the layer-0 input, so
        # nothing can be skipped
        diff = (s.stq != parent.stq).any(axis=(0, 2))          # (2L-1,)
        l0 = L - 1
        for r in np.nonzero(diff)[0]:
            r = int(r)
            layer = 0 if r == L - 1 else (r if r < L - 1 else r - L)
            l0 = min(l0, layer)
        if l0 < 1:
            return None
        return ("resume", parent, l0)

    def _relax_full(self, states: List[_CohortState]) -> None:
        """Full-chain relaxation: one fused launch across every state when
        the (D*M, L-1, N, N) stack fits the residency budget, the chunked
        loop when it does not (``REPRO_RELAX_CHUNK_BYTES`` shrinks the
        budget; tiny values force the fallback — see the chunking tests)."""
        Ms = [s.steep.shape[0] for s in states]   # per-state mode counts
        B = sum(Ms)                               # (tighten states carry 1)
        N, Gp1 = self.N, self.gamma + 1
        steep = np.concatenate([s.steep for s in states])      # (B, ...)
        grid = np.concatenate([s.grid for s in states])
        E = np.broadcast_to(self._proto._ext.E[None],
                            (B,) + self._proto._ext.E.shape)
        lo = self.depth_window_lo
        if self.backend == "mesh":
            hist, par = self._mesh().relax(grid, E, steep, lo)
            self.stats.fused_relaxes += 1
        else:
            chunk = relax_chunk_rows(N * N * Gp1 * 16)
            if B <= chunk:
                hist, par = self._relax_batch(grid, E, steep, lo)
                self.stats.fused_relaxes += 1
            else:
                hists, pars = [], []
                for start in range(0, B, chunk):
                    sl = slice(start, start + chunk)
                    h, p = self._relax_batch(grid[sl], E[sl], steep[sl], lo)
                    hists.append(h)
                    pars.append(p)
                hist = np.concatenate(hists)
                par = np.concatenate(pars)
                self.stats.chunked_relaxes += 1
        off = 0
        for s, m in zip(states, Ms):
            s.dps = [_BandedArgDP(hist[off + mi], par[off + mi],
                                  s.steep[mi]) for mi in range(m)]
            off += m

    def _relax_batch(self, grid: np.ndarray, E: np.ndarray,
                     steep: np.ndarray, lo: Optional[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        if self._engine == "banded":
            return batched_banded_relax_minarg(grid, E, steep, lo)
        return batched_banded_relax_argmin(
            grid, np.ascontiguousarray(E), steep, lo, backend=self._engine)

    def _relax_resume(self, l0: int,
                      pairs: List[Tuple[_CohortState, _CohortState]]
                      ) -> None:
        """Bounded re-relaxation: seed a relax over layers ``l0:`` with the
        parents' saved block-``l0`` grid slices and splice the untouched
        hist/par prefixes back in.  Bit-exact vs the full chain because the
        depth-window masking is DEPTH-based, not layer-position-based
        (``bellman_ford._banded_gather_idx``), so the suffix relax applies
        exactly the ops the full chain would from block ``l0`` on."""
        M = self.M
        lo = self.depth_window_lo
        init = np.stack([pr.dps[mi].hist[l0]
                         for s, pr in pairs for mi in range(M)])
        steep = np.concatenate([s.steep[:, l0:] for s, _pr in pairs])
        E_one = self._proto._ext.E[l0:]
        E = np.broadcast_to(E_one[None], (len(init),) + E_one.shape)
        hist, par = batched_banded_relax_minarg(init, E, steep, lo)
        for i, (s, pr) in enumerate(pairs):
            dps = []
            for mi in range(M):
                pd = pr.dps[mi]
                h = np.concatenate([pd.hist[:l0], hist[i * M + mi]])
                pn = np.concatenate([pd.par_n[:l0], par[i * M + mi]])
                dps.append(_BandedArgDP(h, pn, s.steep[mi]))
            s.dps = dps

    def _mesh(self):
        if self._mesh_relaxer is None:
            from repro.sharding.population import MeshRelaxer
            self._mesh_relaxer = MeshRelaxer()
        return self._mesh_relaxer

    # ------------------------------------------------------------- post-pass
    def _exit_candidates(self, state: _CohortState, mi: int, k: int):
        """Lazy energy-ordered candidates at exit ``k`` — the sequence of
        ``fin._iter_configs_at_exit``, cached on the cohort state so every
        user sharing the state shares one backtrack."""
        cache = state.cand.get((mi, k))
        if cache is None:
            cache = state.cand[(mi, k)] = _CandCache()
        i = 0
        while True:
            while i < len(cache.items):
                yield cache.items[i]
                i += 1
            if cache.exhausted:
                return
            self._extend_candidates(state, mi, k, cache)

    def _extend_candidates(self, state: _CohortState, mi: int, k: int,
                           cache: _CandCache) -> None:
        dp = state.dps[mi]
        block = self.profile.exits[k].block
        d = dp.dist[block]                        # (N, G+1, 1)
        if not cache.items:
            # fast path of _iter_configs_at_exit: cheapest state via argmin
            j0 = int(np.argmin(d))
            v0 = float(d.ravel()[j0])
            if not np.isfinite(v0):
                cache.exhausted = True
                return
            n0, g0, r0 = np.unravel_index(j0, d.shape)
            cfg = Config(placement=_backtrack(dp, block, int(n0), int(g0),
                                              int(r0)), final_exit=k)
            cache.items.append((cfg, v0))
            return
        if cache.order is None:
            order = np.argsort(d, axis=None, kind="stable")
            vals = d.ravel()[order]
            cache.order = (order, vals, int(np.searchsorted(vals, np.inf)))
        order, vals, n_finite = cache.order
        j = len(cache.items)
        if j >= n_finite:
            cache.exhausted = True
            return
        n_, g_, r_ = np.unravel_index(int(order[j]), d.shape)
        cfg = Config(placement=_backtrack(dp, block, int(n_), int(g_),
                                          int(r_)), final_exit=k)
        cache.items.append((cfg, float(vals[j])))

    def _candidate(self, state: _CohortState, mi: int, k: int,
                   j: int) -> Optional[Tuple[Config, float]]:
        """Indexed access into the shared per-state candidate frontier:
        the j-th energy-ordered candidate at exit ``k`` (lazily extended),
        or None when the exit's candidates are exhausted."""
        cache = state.cand.get((mi, k))
        if cache is None:
            cache = state.cand[(mi, k)] = _CandCache()
        while len(cache.items) <= j and not cache.exhausted:
            self._extend_candidates(state, mi, k, cache)
        return cache.items[j] if j < len(cache.items) else None

    def _eval_users_factory(self, bwv: np.ndarray):
        """Bind the cohort's shared tensors into a vectorized exact
        evaluator over the given (Us, N) per-user bandwidth rows."""
        prof, req = self.profile, self.req
        nodes = self.network0.nodes
        base_bw = self._proto._bw
        comp = self._proto._compute
        src = self.src
        chk = self.check_aggregate_load

        def ev(cfg: Config, idx: np.ndarray):
            return eval_config_users(prof, req, nodes, base_bw, comp, src,
                                     cfg, bwv[idx],
                                     check_aggregate_load=chk)
        return ev

    def _scan_state_group(self, state: _CohortState, bwv: np.ndarray):
        """``_solve_one``'s control flow vectorized over a whole user batch
        sharing one cohort state: the main-pass scan, the ceil rescue pass
        bounded by the main pass's per-user energies, and the rare
        no-feasible fallback — all (candidate, user) pairs scored as
        stacked arrays (``frontier.scan_state_users``), with per-user
        selections bit-identical to the scalar post-pass.

        Returns (cfgs, energy, lat, e_comp, e_comm, used_ceil, exit_, fb):
        per-user chosen Config references (shared candidate objects, None
        where nothing was found), their exact objective parts, the
        ceil-pass markers and per-user fallback Solutions (None except on
        the tighten path).
        """
        Us = len(bwv)
        adm = self._proto._admissible
        ev = self._eval_users_factory(bwv)
        s0 = scan_state_users(
            state.dps[0], self.profile, adm,
            lambda k, j: self._candidate(state, 0, k, j), ev, Us,
            dist_tol=self._dist_tol)
        cfgs: List[Optional[Config]] = [None] * Us
        fb: List[Optional[Solution]] = [None] * Us
        energy = s0.energy.copy()
        lat = s0.latency.copy()
        e_comp = s0.e_comp.copy()
        e_comm = s0.e_comm.copy()
        exit_ = s0.exit.copy()
        cand_ = s0.cand.copy()
        mi_ = np.zeros(Us, dtype=np.int64)
        used_ceil = np.zeros(Us, dtype=bool)
        fb_mask = ~s0.found & (self.max_tighten > 0)
        fb_idx = np.nonzero(fb_mask)[0]
        no_exit = not adm
        tb = None
        if len(fb_idx):
            # batched Plan.solve tighten loop (round 0 already failed via
            # the s0 scan above — bit-exact, same dp, same scan contract)
            tF = time.perf_counter() if self._timing else 0.0
            self.stats.fallbacks += len(fb_idx)
            if not no_exit:
                tb = self._tighten_batch(bwv[fb_idx], state)
            if self._timing:
                self.stats.t_post_fallback_ms += \
                    (time.perf_counter() - tF) * 1e3
        s1 = None
        if self.quantize != "ceil" and (len(fb_idx) < Us or tb is not None):
            # one ceil rescue scan for everyone: the non-fallback users
            # bounded by their main-pass energies (the old subset scan),
            # the fallback users bounded by their tighten energies —
            # exactly Plan.solve's ``_scan(dps[1], best)``
            bound = np.where(s0.found, s0.energy, np.nan)
            if tb is not None:
                bound[fb_idx] = np.where(tb.found, tb.energy, np.nan)
            s1 = scan_state_users(
                state.dps[1], self.profile, adm,
                lambda k, j: self._candidate(state, 1, k, j),
                ev, Us, dist_tol=self._dist_tol, bound_energy=bound)
            take = s1.found & (~s0.found | (s1.energy < energy)) & ~fb_mask
            t = np.nonzero(take)[0]
            exit_[t] = s1.exit[take]
            cand_[t] = s1.cand[take]
            mi_[t] = 1
            energy[t] = s1.energy[take]
            lat[t] = s1.latency[take]
            e_comp[t] = s1.e_comp[take]
            e_comm[t] = s1.e_comm[take]
            used_ceil[t] = True
        for i in np.nonzero(~fb_mask)[0]:
            if exit_[i] >= 0:
                cfgs[i] = self._candidate(state, int(mi_[i]), int(exit_[i]),
                                          int(cand_[i]))[0]
        if len(fb_idx):
            self._tighten_assemble(fb, fb_idx, tb, s1, state, no_exit)
        return cfgs, energy, lat, e_comp, e_comm, used_ceil, exit_, fb

    def _scan_state(self, state: _CohortState, mi: int, network: Network,
                    bound=None):
        return _best_feasible(
            network, self.profile, self.req, state.dps[mi],
            self._proto._admissible, self.check_aggregate_load,
            oracle=False, bound=bound, dist_tol=self._dist_tol,
            candidates=lambda k: self._exit_candidates(state, mi, k))

    def _user_network(self, bw_row: np.ndarray) -> Network:
        bw = self._proto._bw.copy()
        src = self.src
        bw[src, :] = bw_row
        bw[:, src] = bw_row
        bw[src, src] = np.inf
        return Network(nodes=list(self.network0.nodes), bandwidth=bw,
                       compute=self._proto._compute, source_node=src)

    def _fallback_solve(self, bw_row: np.ndarray,
                        mask: np.ndarray) -> Solution:
        """Exact rare-path solve (tighten loop / no-feasible round 0): one
        persistent warm Plan per cohort replays the user's (bandwidth,
        mask) state and runs the whole ``Plan.solve`` control flow, whose
        warm==cold invariant is property-tested.  Warm deltas on the kept
        plan cost microseconds where a fresh Plan build costs milliseconds
        — and users with no feasible placement hit this path every tick
        they stay dirty."""
        t0 = time.perf_counter() if self._timing else 0.0
        plan = self._fallback_plan
        if plan is None:
            plan = self._fallback_plan = Plan(
                self.network0, self.profile, self.req, gamma=self.gamma,
                lam=self.lam, quantize=self.quantize,
                max_tighten=self.max_tighten,
                tighten_factor=self.tighten_factor, n_best=1,
                backend=self._plan_backend,
                check_aggregate_load=self.check_aggregate_load)
        plan.update_uplink(bw_row)
        have = plan._masked.copy()
        for n in np.nonzero(mask & ~have)[0]:
            plan.mask_node(int(n))
        for n in np.nonzero(have & ~mask)[0]:
            plan.unmask_node(int(n))
        self.stats.fallbacks += 1
        sol = plan.solve()
        if self._timing:
            self.stats.t_post_fallback_ms += \
                (time.perf_counter() - t0) * 1e3
        return sol

    def _tighten_consts(self, delta_eff: float) -> QuantConsts:
        """Single-mode constants bundle for one tighten round: the same
        bandwidth-independent packs as the base requantizer, quantized
        against ``delta_eff`` with only the main quantizer mode."""
        base = self._quant()
        return QuantConsts(bits_pack=base.bits_pack, C_pack=base.C_pack,
                           mask_pack=base.mask_pack,
                           load_pack=base.load_pack,
                           modes=(self.quantize,), gamma=self.gamma,
                           delta=float(delta_eff))

    def _tighten_state(self, round_: int, enc_row: np.ndarray,
                       mask: np.ndarray, delta_eff: float) -> _CohortState:
        """A (relaxable) single-mode cohort state for one tighten cell:
        non-source steepness from a per-round ``build_feasible_graph`` at
        ``delta_eff`` (shared by every user — those links' bandwidths are
        cohort-wide), source rows/cols and init depths scattered from the
        user pack, exactly ``Plan._feasible``'s tensors.  Cached by
        (round, signature, mask) OUTSIDE the main state table — a
        tightened signature must never collide with a base-delta key."""
        key = (round_, enc_row.tobytes(), mask.tobytes())
        st = self._tighten_cache.get(key)
        if st is not None:
            return st
        base = self._tighten_base.get(round_)
        if base is None:
            self._proto._flush_ext()
            fg = build_feasible_graph(self._proto._ext, self.gamma,
                                      lam=self.lam, quantize=self.quantize,
                                      delta_eff=delta_eff)
            base = self._tighten_base[round_] = fg.steep[None].copy()
        stq = _dec_int16(enc_row).reshape(1, 2 * self.L - 1, self.N)
        steep, grid = self._state_tensors(stq, mask, base_steep=base)
        st = _CohortState(stq, mask, steep, grid)
        if len(self._tighten_cache) >= 8192:   # adversarial-churn bound
            self._tighten_cache.clear()
        self._tighten_cache[key] = st
        return st

    def _tighten_batch(self, bwv_fb: np.ndarray,
                       state: _CohortState) -> "_TightenResult":
        """``Plan.solve``'s tighten loop batched over every no-feasible
        user of one cohort state.  Per round: ONE fused requantize of the
        still-unsolved rows at the round's ``delta_eff``, dedupe into
        tighten cells, ONE fused relaxation of the unseen cells, and one
        vectorized scan per cell — per-user results bit-exact vs the
        scalar per-user ``Plan.solve`` replay (rounds are per-user
        independent, the dp for a signature is unique, and the scan
        contract is the PR-5 one).  Steady-state churn revisits the same
        cells, so the cache turns the whole herd into pure scans."""
        F = len(bwv_fb)
        res = _TightenResult(F, self.max_tighten)
        adm = self._proto._admissible
        alive = np.arange(F)
        delta_eff = self.req.delta
        for r in range(1, self.max_tighten + 1):
            delta_eff *= self.tighten_factor    # Plan's own accumulation
            if not len(alive):
                break
            enc = quant_signature(bwv_fb[alive],
                                  self._tighten_consts(delta_eff),
                                  backend=self._ingest_backend)
            enc = np.ascontiguousarray(enc)
            v = enc.view(np.dtype((np.void,
                                   enc.shape[1] * enc.dtype.itemsize)))
            _uniq, inv = np.unique(v.ravel(), return_inverse=True)
            groups = [np.nonzero(inv == g)[0] for g in range(len(_uniq))]
            sts = [self._tighten_state(r, enc[g[0]], state.mask, delta_eff)
                   for g in groups]
            fresh = [st for st in sts if st.dps is None]
            if fresh:
                self._relax_full(fresh)
            still = []
            for st, g in zip(sts, groups):
                members = alive[g]
                sc = scan_state_users(
                    st.dps[0], self.profile, adm,
                    lambda k, j, st=st: self._candidate(st, 0, k, j),
                    self._eval_users_factory(bwv_fb[members]), len(members),
                    dist_tol=self._dist_tol)
                hit = sc.found
                hu = members[hit]
                res.found[hu] = True
                res.energy[hu] = sc.energy[hit]
                res.latency[hu] = sc.latency[hit]
                res.e_comp[hu] = sc.e_comp[hit]
                res.e_comm[hu] = sc.e_comm[hit]
                res.exit[hu] = sc.exit[hit]
                res.rounds[hu] = r
                res.delta_eff[hu] = delta_eff
                for p, k, c in zip(hu, sc.exit[hit], sc.cand[hit]):
                    res.cfgs[p] = self._candidate(st, 0, int(k),
                                                  int(c))[0]
                still.append(members[~hit])
            alive = (np.concatenate(still) if still
                     else np.empty(0, dtype=np.int64))
        if len(alive):
            # Plan multiplies once more after the last failed round; the
            # ceil rescue (if it lands) reports that final delta_eff
            res.delta_eff[alive] = delta_eff * self.tighten_factor
        return res

    def _tighten_assemble(self, fb: List[Optional[Solution]],
                          fb_idx: np.ndarray,
                          tb: Optional["_TightenResult"], s1,
                          state: _CohortState, no_exit: bool) -> None:
        """Fold the batched tighten results and the shared ceil-rescue
        scan into per-user ``Solution``s shaped like ``Plan.solve``'s
        (config/eval bit-identical; meta carries the same tighten_rounds /
        delta_eff / used_ceil_pass bookkeeping)."""
        base_meta = {"gamma": self.gamma, "quantize": self.quantize,
                     "backend": self._plan_backend, "warm": True,
                     "population": True}
        if no_exit:
            m = {**base_meta, "tighten_rounds": 0,
                 "reason": "no exit meets alpha (3c)"}
            for i in fb_idx:
                fb[i] = Solution(config=None, eval=None, solve_time=0.0,
                                 solver="fin", meta=m)
            return
        sigma = self.req.sigma
        for p, i in enumerate(fb_idx):
            meta = {**base_meta, "tighten_rounds": int(tb.rounds[p])}
            ceil_take = (s1 is not None and s1.found[i]
                         and (not tb.found[p]
                              or s1.energy[i] < tb.energy[p]))
            if ceil_take:
                k = int(s1.exit[i])
                cfg = self._candidate(state, 1, k, int(s1.cand[i]))[0]
                ev = ConfigEval(energy=float(s1.energy[i]),
                                energy_comp=float(s1.e_comp[i]),
                                energy_comm=float(s1.e_comm[i]),
                                latency=float(s1.latency[i]),
                                accuracy=self.profile.accuracy_of(k),
                                feasible=True, violations=[])
                meta["used_ceil_pass"] = True
            elif tb.found[p]:
                k = int(tb.exit[p])
                cfg = tb.cfgs[p]
                ev = ConfigEval(energy=float(tb.energy[p]),
                                energy_comp=float(tb.e_comp[p]),
                                energy_comm=float(tb.e_comm[p]),
                                latency=float(tb.latency[p]),
                                accuracy=self.profile.accuracy_of(k),
                                feasible=True, violations=[])
            else:
                fb[i] = Solution(config=None, eval=None, solve_time=0.0,
                                 solver="fin",
                                 meta={**meta,
                                       "reason": "no feasible path"})
                continue
            ev._energy_rate = sigma * ev.energy
            meta["delta_eff"] = float(tb.delta_eff[p])
            meta["n_feasible_states"] = 1
            fb[i] = Solution(config=cfg, eval=ev, solve_time=0.0,
                             solver="fin", meta=meta)

    def _solve_one(self, state: _CohortState, bw_row: np.ndarray
                   ) -> Tuple[Optional[Config], Optional[ConfigEval], dict]:
        """``Plan.solve``'s control flow against a shared cohort state and
        one user's true bandwidth (the exact post-pass input)."""
        meta = {"gamma": self.gamma, "quantize": self.quantize,
                "tighten_rounds": 0, "backend": self.backend,
                "warm": True, "population": True}
        if not self._proto._admissible:
            return None, None, {**meta, "reason": "no exit meets alpha (3c)"}
        network = self._user_network(bw_row)
        best = self._scan_state(state, 0, network)
        if best is None and self.max_tighten > 0:
            sol = self._fallback_solve(bw_row, state.mask)
            return sol.config, sol.eval, sol.meta
        if self.quantize != "ceil":
            alt = self._scan_state(state, 1, network, bound=best)
            if alt is not None and (best is None
                                    or alt[1].energy < best[1].energy):
                best = alt
                meta["used_ceil_pass"] = True
        if best is None:
            return None, None, {**meta, "reason": "no feasible path"}
        cfg, ev = best
        meta["delta_eff"] = self.req.delta
        meta["n_feasible_states"] = int(np.isfinite(ev.energy))
        return cfg, ev, meta

    # ----------------------------------------------------------------- solve
    def solve(self, users: Optional[np.ndarray] = None,
              build_solutions: bool = True) -> Optional[List[Solution]]:
        """Warm re-solve of the given users (default: whole cohort).

        Relaxes exactly the cohort states born since their last relax, then
        runs the exact post-pass once per unique (state, true-bandwidth)
        group — users with identical channel state share one solve.  With
        the default vectorized post-pass the unique groups of each cohort
        state are scored together as stacked arrays (``frontier.
        scan_state_users``) — per-user selections are bit-identical to the
        scalar per-group path (``vector_postpass=False``), which the
        ``always_resolve`` benchmarks keep as the same-machine oracle.
        Updates the incumbents in place; returns the per-user Solutions
        when ``build_solutions`` (pass False on million-user ticks to skip
        materializing U Python objects — the incumbent arrays carry the
        results either way).
        """
        return self.solve_finish(
            self.solve_begin(users, build_solutions=build_solutions))

    def attach_many(self, bps: Union[float, np.ndarray, None] = None,
                    users: Optional[np.ndarray] = None, *,
                    build_solutions: bool = False) -> "Population":
        """Bulk cold-start attach: land the given users' source-link
        bandwidths (scalar / (Us,) / (Us, N), like :meth:`ingest`; None
        keeps the base-topology uplink every user is born with) and build
        their signatures, cohort states, fast tables and incumbents in one
        grouped pass — signature hashing runs only over the rows whose
        encoding moved off the shared cold-start state, the newborn states
        relax in one fused launch, and the incumbents land through the
        shared fast tables with no per-user Python.  Defaults to
        ``build_solutions=False`` (the incumbent arrays carry the result;
        at 1e7 users materializing U Solution objects is the cold start).

        Returns ``self`` — ``Population(...).attach_many(rates)`` is the
        whole cold start.
        """
        users = (np.arange(self.U) if users is None
                 else np.asarray(users, dtype=np.int64))
        if bps is not None:
            self.ingest(bps, users=users, requant=False)
        self.solve(users, build_solutions=build_solutions)
        return self

    def solve_begin(self, users: Optional[np.ndarray] = None,
                    build_solutions: bool = True, *,
                    stream: bool = False) -> "_PendingSolve":
        """Phase 1 of a tick's solve: flush deferred requants, snapshot the
        (state, bandwidth) inputs, group identical rows and LAUNCH the
        newborn relaxation.  ``stream=True`` runs the relaxation on a
        background thread so the caller can overlap the NEXT tick's
        numpy-side ingest with this tick's in-flight relax (the streaming
        pipeline); the handle must be redeemed with :meth:`solve_finish`
        before any call that mutates cohort states (ingest with
        ``requant=False`` only touches the bandwidth store and is safe to
        overlap).  Results are bit-identical to :meth:`solve` — the
        post-pass reads this snapshot, not the live bandwidth."""
        t0 = time.perf_counter()
        users = (np.arange(self.U) if users is None
                 else np.asarray(users, dtype=np.int64))
        Us = len(users)
        pend = _PendingSolve(users, build_solutions, t0)
        if Us == 0:
            return pend
        self._refresh_states(users)
        self._last_relax_s = 0.0     # this tick's relax only (EWMA signal)
        sids = self._user_state[users]
        uniq_sids = np.unique(sids)
        need = [int(s) for s in uniq_sids if self._states[int(s)].dps is None]
        if need and stream:
            pend.future = self._executor().submit(self._relax_states, need)
        elif need:
            self._relax_states(need)
        self.stats.dp_cache_hits += Us - len(need)
        self.stats.solves += Us

        # unique (state, bandwidth) groups: identical inputs, one solve
        rows = np.empty((Us, 1 + self.N), dtype=np.float64)
        rows[:, 0] = sids
        rows[:, 1:] = self._bw_rows(users)
        v = np.ascontiguousarray(rows).view(
            np.dtype((np.void, rows.shape[1] * 8))).ravel()
        _, first, order, bounds = _group_runs(v)
        pend.sids = sids
        pend.first, pend.order, pend.bounds = first, order, bounds
        pend.bw = rows[:, 1:]            # the tick's bandwidth snapshot
        return pend

    def solve_finish(self, pend: "_PendingSolve"
                     ) -> Optional[List[Solution]]:
        """Phase 2: join the in-flight relaxation (if streaming) and run
        the exact post-pass against the snapshot taken at begin-time."""
        users = pend.users
        Us = len(users)
        if Us == 0:
            return [] if pend.build_solutions else None
        if pend.future is not None:
            pend.future.result()
            pend.future = None
        t1 = time.perf_counter()
        first, order, bounds = pend.first, pend.order, pend.bounds
        dt_share = (t1 - pend.t0) / Us

        if self._vector_postpass and self._proto._admissible:
            self._solve_vectorized(users, pend.sids, first, order, bounds,
                                   dt_share, pend.build_solutions, pend.bw)
        else:
            for g, j in enumerate(first):
                state = self._states[int(pend.sids[j])]
                cfg, ev, meta = self._solve_one(state, pend.bw[j])
                members = users[order[bounds[g]:bounds[g + 1]]]
                self._record_group(members, cfg, ev, meta, dt_share,
                                   pend.build_solutions)
        self.stats.unique_solves += len(first)
        if self._timing:
            self.stats.t_post_ms += (time.perf_counter() - t1) * 1e3
        return self.solutions(users) if pend.build_solutions else None

    def _executor(self):
        if self._relax_executor is None:
            from concurrent.futures import ThreadPoolExecutor
            self._relax_executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="pop-relax")
        return self._relax_executor

    def _build_fast(self, state: _CohortState) -> _FastTable:
        """Materialize the state's shared first-candidate decision (see
        :class:`_FastTable`): replay the scalar post-pass's control flow
        over the FIRST candidate of each (quantizer pass, admissible exit)
        using the bandwidth-independent exact energies — one exact
        evaluation per distinct configuration, memoized cohort-wide."""
        adm = self._proto._admissible
        prof = self.profile
        keys: List[Tuple] = []
        cfgs: List[Config] = []
        pos_of: Dict[Tuple, int] = {}

        def cand0(mi: int, k: int) -> Optional[int]:
            item = self._candidate(state, mi, k, 0)
            if item is None:
                return None
            cfg = item[0]
            key = (cfg.final_exit, tuple(cfg.placement))
            p = pos_of.get(key)
            if p is None:
                p = pos_of[key] = len(cfgs)
                keys.append(key)
                cfgs.append(cfg)
            return p

        def energy(p: int) -> Tuple[float, float, float]:
            ent = self._cfg_energy.get(keys[p])
            if ent is None:
                e, ec, em, _lat, _v = eval_config_users(
                    prof, self.req, self.network0.nodes, self._proto._bw,
                    self._proto._compute, self.src, cfgs[p],
                    self._bw_rows(np.arange(1)),
                    check_aggregate_load=self.check_aggregate_load)
                ent = self._cfg_energy[keys[p]] = (e, ec, em)
            return ent

        tol = self._dist_tol
        scan: List[Tuple[int, int, int]] = []
        found = None                    # (energy, mi, k, pos, ec, em)
        for k in adm:
            dmin = _exit_dmin(state.dps[0], prof.exits[k].block)
            if found is not None and dmin > found[0] * (1.0 + tol):
                continue
            p = cand0(0, k)
            if p is None:
                continue
            scan.append((0, k, p))
            e, ec, em = energy(p)
            if found is None or e < found[0]:
                found = (e, 0, k, p, ec, em)
        used_ceil = False
        if self.quantize != "ceil":
            bound = found[0] if found is not None else None
            alt = None
            for k in adm:
                dmin = _exit_dmin(state.dps[1], prof.exits[k].block)
                be = alt[0] if alt is not None else bound
                if be is not None and dmin > be * (1.0 + tol):
                    continue
                p = cand0(1, k)
                if p is None:
                    continue
                scan.append((1, k, p))
                e, ec, em = energy(p)
                if alt is None or e < alt[0]:
                    alt = (e, 1, k, p, ec, em)
            if alt is not None and (found is None or alt[0] < found[0]):
                found = alt
                used_ceil = True
        choice = None
        if found is not None:
            e, mi, k, p, ec, em = found
            choice = (mi, k, p, e, ec, em, used_ceil)
        state.fast = _FastTable(keys, cfgs, scan, choice)
        return state.fast

    def _solve_vectorized(self, users: np.ndarray, sids: np.ndarray,
                          first: np.ndarray, order: np.ndarray,
                          bounds: np.ndarray, dt_share: float,
                          build_solutions: bool,
                          bw: Optional[np.ndarray] = None) -> None:
        """Vectorized frontier post-pass over the unique (state, bandwidth)
        representatives.

        Fast path: the distinct first-candidate configurations of every
        touched state are evaluated ONCE each for ALL representatives as
        stacked feasibility arrays; a state whose scanned first candidates
        are feasible for every representative broadcasts its cached
        ``_FastTable`` choice (exact energies are bandwidth-independent, so
        the selection is shared).  States with any first-candidate
        violation fall back to the general per-state scan
        (``_scan_state_group``); both are bit-identical to the scalar
        per-group post-pass.
        """
        tA = time.perf_counter() if self._timing else 0.0
        reps = users[first]
        rep_sids = sids[first]
        uniq_s, _f, s_order, s_bounds = _group_runs(rep_sids)
        states = [self._states[int(s)] for s in uniq_s]
        tables = [st.fast if st.fast is not None else self._build_fast(st)
                  for st in states]

        # distinct scanned configs across states -> one stacked-feasibility
        # evaluation each, over exactly the representatives of the states
        # that reference the config (cohort states sharing a first
        # candidate share the evaluation; disjoint states do not pay for
        # each other's rows — unevaluated (row, rep) cells are never read)
        key2row: Dict[Tuple, int] = {}
        tasks: List[Config] = []
        task_rpos: List[List[np.ndarray]] = []
        for gi, ft in enumerate(tables):
            rpos = s_order[s_bounds[gi]:s_bounds[gi + 1]]
            for key, cfg in zip(ft.keys, ft.cfgs):
                r = key2row.get(key)
                if r is None:
                    r = key2row[key] = len(tasks)
                    tasks.append(cfg)
                    task_rpos.append([])
                task_rpos[r].append(rpos)
        bw_reps = self._bw_rows(reps) if bw is None else bw[first]
        nR = len(reps)
        violM = np.ones((len(tasks), nR), dtype=bool)
        latM = np.empty((len(tasks), nR))
        for r, cfg in enumerate(tasks):
            cols = (task_rpos[r][0] if len(task_rpos[r]) == 1
                    else np.unique(np.concatenate(task_rpos[r])))
            _e, _ec, _em, lat, viol = eval_config_users(
                self.profile, self.req, self.network0.nodes,
                self._proto._bw, self._proto._compute, self.src, cfg,
                bw_reps[cols], check_aggregate_load=self.check_aggregate_load)
            violM[r, cols] = viol
            latM[r, cols] = lat
        if self._timing:
            # shared-table machinery: fast-table builds + the stacked
            # first-candidate feasibility evaluations
            self.stats.t_post_fast_ms += (time.perf_counter() - tA) * 1e3

        base_meta = {"gamma": self.gamma, "quantize": self.quantize,
                     "tighten_rounds": 0, "backend": self.backend,
                     "warm": True, "population": True}
        fast_meta = {**base_meta, "delta_eff": self.req.delta,
                     "n_feasible_states": 1}
        for gi, (state, ft) in enumerate(zip(states, tables)):
            rpos = s_order[s_bounds[gi]:s_bounds[gi + 1]]
            ids = [key2row[k] for k in ft.keys]
            scan_rows = sorted({ids[p] for _mi, _k, p in ft.scan})
            ok = (not scan_rows
                  or not violM[np.ix_(scan_rows, rpos)].any())
            if ok and ft.choice is not None:
                mi, k, p, e, ec, em, used_ceil = ft.choice
                cfg = ft.cfgs[p]
                self.stats.fastpath_states += 1
                if not build_solutions:
                    members = (users[order[bounds[rpos[0]]:
                                           bounds[rpos[0] + 1]]]
                               if len(rpos) == 1 else
                               np.concatenate(
                                   [users[order[bounds[rp]:bounds[rp + 1]]]
                                    for rp in rpos]))
                    self._record_fast(members, cfg, e)
                    continue
                row = ids[p]
                meta = ({**fast_meta, "used_ceil_pass": True} if used_ceil
                        else dict(fast_meta))
                acc = self.profile.accuracy_of(k)
                for rp in rpos:
                    members = users[order[bounds[rp]:bounds[rp + 1]]]
                    ev = ConfigEval(energy=e, energy_comp=ec,
                                    energy_comm=em,
                                    latency=float(latM[row, rp]),
                                    accuracy=acc, feasible=True,
                                    violations=[])
                    ev._energy_rate = self.req.sigma * e
                    self._record_group(members, cfg, ev, meta, dt_share,
                                       True)
                continue
            if ok and ft.choice is None:
                # no DP candidates at any admissible exit: the tighten
                # fallback (or a no-feasible-path record), per the scalar
                # control flow
                for rp in rpos:
                    members = users[order[bounds[rp]:bounds[rp + 1]]]
                    if self.max_tighten > 0:
                        sol = self._fallback_solve(bw_reps[rp], state.mask)
                        self._record_group(members, sol.config, sol.eval,
                                           sol.meta, dt_share,
                                           build_solutions)
                    else:
                        meta = {**base_meta, "reason": "no feasible path"}
                        self._record_group(members, None, None, meta,
                                           dt_share, build_solutions)
                continue
            # general path: full vectorized scan for this state's reps
            tS = time.perf_counter() if self._timing else 0.0
            cfgs, energy, lat, e_comp, e_comm, used_ceil_a, exit_, fb = \
                self._scan_state_group(state, bw_reps[rpos])
            if self._timing:
                self.stats.t_post_scan_ms += \
                    (time.perf_counter() - tS) * 1e3
            for pi, rp in enumerate(rpos):
                members = users[order[bounds[rp]:bounds[rp + 1]]]
                if fb[pi] is not None:
                    sol = fb[pi]
                    self._record_group(members, sol.config, sol.eval,
                                       sol.meta, dt_share, build_solutions)
                    continue
                cfg = cfgs[pi]
                if cfg is None:
                    meta = {**base_meta, "reason": "no feasible path"}
                    self._record_group(members, None, None, meta, dt_share,
                                       build_solutions)
                    continue
                if build_solutions:
                    ev = ConfigEval(
                        energy=float(energy[pi]),
                        energy_comp=float(e_comp[pi]),
                        energy_comm=float(e_comm[pi]),
                        latency=float(lat[pi]),
                        accuracy=self.profile.accuracy_of(int(exit_[pi])),
                        feasible=True, violations=[])
                    ev._energy_rate = self.req.sigma * ev.energy
                    meta = {**base_meta, "delta_eff": self.req.delta,
                            "n_feasible_states": 1}
                    if used_ceil_a[pi]:
                        meta["used_ceil_pass"] = True
                    self._record_group(members, cfg, ev, meta, dt_share,
                                       True)
                else:
                    self._record_fast(members, cfg, float(energy[pi]))

    def _note_incumbent(self, members: np.ndarray,
                        cfg: Optional[Config]) -> None:
        """Maintain the uniform-incumbent flag across a recording: a
        whole-cohort record (re)establishes uniformity, a partial record
        keeps it only when it installs the same configuration."""
        if cfg is None:
            if len(members) == self.U or self._inc_single is not None:
                self._inc_single = None
            return
        key = (cfg.final_exit, tuple(int(n) for n in cfg.placement))
        if len(members) == self.U:
            self._inc_single = key
        elif self._inc_single is not None and self._inc_single != key:
            self._inc_single = None

    def _record_fast(self, members: np.ndarray, cfg: Config,
                     energy: float) -> None:
        """Incumbent-arrays-only recording (build_solutions=False path)."""
        self._solved[members] = True
        nb = len(cfg.placement)
        self._inc_place[members, :nb] = cfg.placement
        self._inc_place[members, nb:] = -1
        self._inc_exit[members] = cfg.final_exit
        self._inc_energy[members] = energy
        if self._any_solutions:
            self._solutions[members] = None
        self._note_incumbent(members, cfg)

    def _record_group(self, members: np.ndarray, cfg: Optional[Config],
                      ev: Optional[ConfigEval], meta: dict, dt: float,
                      build_solutions: bool) -> None:
        self._solved[members] = True
        if cfg is None:
            self._inc_place[members] = -1
            self._inc_exit[members] = -1
            self._inc_energy[members] = np.inf
        else:
            nb = len(cfg.placement)
            self._inc_place[members, :nb] = cfg.placement
            self._inc_place[members, nb:] = -1
            self._inc_exit[members] = cfg.final_exit
            self._inc_energy[members] = ev.energy
        if build_solutions:
            self._solutions[members] = Solution(
                config=cfg, eval=ev, solve_time=dt, solver="fin",
                meta=meta)
            self._any_solutions = True
        elif self._any_solutions:
            self._solutions[members] = None
        self._note_incumbent(members, cfg)

    # -------------------------------------------------------------- frontier
    def frontiers(self, users: np.ndarray, *,
                  k_per_exit: Optional[int] = 4) -> List[ParetoFrontier]:
        """Per-user k-best Pareto frontiers (core/frontier.py).

        The candidate rows are the per-cohort-state energy-ordered
        backtracks (shared across every user in a state — one backtrack
        per candidate for the whole cohort), exact-evaluated against each
        user's true bandwidth as stacked arrays and dominance-pruned per
        user (latency feasibility is per-user, so so is the frontier).
        Each frontier's ``argmin`` row is exactly the user's
        ``Population.solve`` selection — the orchestrator's frontier
        policy degrades to the argmin policy row by row.
        """
        users = np.asarray(users, dtype=np.int64)
        Us = len(users)
        out: List[Optional[ParetoFrontier]] = [None] * Us
        if Us == 0:
            return []
        if not self._proto._admissible:
            return [ParetoFrontier([], None) for _ in range(Us)]
        self._refresh_states(users)
        sids = self._user_state[users]
        need = [int(s) for s in np.unique(sids)
                if self._states[int(s)].dps is None]
        self._relax_states(need)
        self.stats.solves += Us
        uniq_s, _f, s_order, s_bounds = _group_runs(sids)
        sigma = self.req.sigma
        for gi in range(len(uniq_s)):
            pos = s_order[s_bounds[gi]:s_bounds[gi + 1]]
            state = self._states[int(uniq_s[gi])]
            bwv = self._bw_rows(users[pos])
            cfgs, energy, lat, e_comp, e_comm, _used_ceil, exit_, fb = \
                self._scan_state_group(state, bwv)
            # candidate rows in the solver's scan order (exit asc, quantizer
            # pass asc, graph-energy asc) — identical to Plan.frontier's
            items: List[Config] = []
            for k in self._proto._admissible:
                for mi in range(self.M):
                    j = 0
                    while k_per_exit is None or j < k_per_exit:
                        it = self._candidate(state, mi, k, j)
                        if it is None:
                            break
                        items.append(it[0])
                        j += 1
            evals = [eval_config_users(
                self.profile, self.req, self.network0.nodes,
                self._proto._bw, self._proto._compute, self.src, cfg, bwv,
                check_aggregate_load=self.check_aggregate_load)
                for cfg in items]
            for pi, p_ in enumerate(pos):
                if fb[pi] is not None:
                    sol = fb[pi]
                    am = (sol.config, sol.eval) if sol.feasible else None
                elif cfgs[pi] is not None:
                    ev0 = ConfigEval(
                        energy=float(energy[pi]),
                        energy_comp=float(e_comp[pi]),
                        energy_comm=float(e_comm[pi]),
                        latency=float(lat[pi]),
                        accuracy=self.profile.accuracy_of(int(exit_[pi])),
                        feasible=True, violations=[])
                    ev0._energy_rate = sigma * ev0.energy
                    am = (cfgs[pi], ev0)
                else:
                    am = None
                pairs = []
                for cfg, (e, ec, em, latr, violr) in zip(items, evals):
                    if violr[pi]:
                        continue
                    evr = ConfigEval(
                        energy=e, energy_comp=ec, energy_comm=em,
                        latency=float(latr[pi]),
                        accuracy=self.profile.accuracy_of(cfg.final_exit),
                        feasible=True, violations=[])
                    evr._energy_rate = sigma * e
                    pairs.append((cfg, evr))
                out[p_] = frontier_from_rows(pairs, am)
        return out

    def frontier(self, u: int, *,
                 k_per_exit: Optional[int] = 4) -> ParetoFrontier:
        """One user's Pareto frontier (see :meth:`frontiers`)."""
        return self.frontiers(np.array([int(u)]), k_per_exit=k_per_exit)[0]

    def set_incumbents(self, users: np.ndarray,
                       cfgs: Sequence[Optional[Config]],
                       energies: Sequence[float]) -> None:
        """Install externally chosen configurations as incumbents.

        The orchestrator's frontier policy may keep a slightly-costlier
        frontier row (or the previous incumbent) when the energy delta
        does not pay for the migration; this records those choices so the
        next tick's hysteresis gate and migration accounting run against
        what is actually deployed."""
        users = np.asarray(users, dtype=np.int64)
        self._inc_single = None      # externally mixed incumbents
        for u, cfg, e in zip(users, cfgs, energies):
            self._solved[u] = True
            if cfg is None:
                self._inc_place[u] = -1
                self._inc_exit[u] = -1
                self._inc_energy[u] = np.inf
            else:
                nb = len(cfg.placement)
                self._inc_place[u, :nb] = cfg.placement
                self._inc_place[u, nb:] = -1
                self._inc_exit[u] = cfg.final_exit
                self._inc_energy[u] = float(e)
            self._solutions[int(u)] = None

    # ------------------------------------------------ incumbent re-evaluation
    def evaluate_incumbents(self, users: Optional[np.ndarray] = None
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Vectorized ``Plan.evaluate(incumbent)`` across users.

        Returns (no_incumbent, feasible, energy) — ``feasible``/``energy``
        are meaningful where ``~no_incumbent``.  Users are grouped by
        incumbent configuration; each group evaluates as one vectorized
        pass whose per-user latency accumulation replays ``evaluate_config``
        term by term (bit-identical doubles), with the failure-bitmap
        dead-node check of ``Plan.evaluate`` applied first.

        ``users=None`` evaluates the whole cohort positionally — the dense
        hysteresis gate's hot path: the incumbent columns are read as
        views, the grouping key is radix-sorted int64 (one all-equal
        compare in the steady single-config state) and a single-group
        cohort reads the bandwidth store with zero per-user gathers.
        When the uniform-incumbent flag is set (every user solved with one
        configuration — the steady state at scale) even the grouping-key
        build is skipped: one stacked evaluation against the bandwidth
        store, results bit-identical to the single-group general path.
        """
        if users is None and self._inc_single is not None:
            k, place_t = self._inc_single
            place = list(place_t)
            cfg = Config(placement=place, final_exit=k)
            e_sc, _lat, viol = self._eval_config_users(
                cfg, self._bw_cols())
            feas = ~viol
            energy = np.full(self.U, e_sc)
            if self._mask_count > 0:
                dead = self._masked[:, place].any(axis=1)
                feas[dead] = False
                energy[dead] = np.inf
            return np.zeros(self.U, dtype=bool), feas, energy
        whole = users is None
        if whole:
            exit_all = self._inc_exit
            place_all = self._inc_place
            solved = self._solved
        else:
            users = np.asarray(users, dtype=np.int64)
            exit_all = self._inc_exit[users]
            place_all = self._inc_place[users]
            solved = self._solved[users]
        Us = len(exit_all)
        feas = np.zeros(Us, dtype=bool)
        energy = np.full(Us, np.inf)
        no_inc = ~solved | (exit_all < 0)
        any_no = bool(no_inc.any())
        if any_no and no_inc.all():
            return no_inc, feas, energy
        # pivot-majority fast path (dense gate at scale): sample the modal
        # incumbent, compare positionally (L+1 cheap int passes — no int64
        # key build, no radix sort), evaluate the pivot config ONCE over
        # the full bandwidth store and re-run only the disagreeing rows
        # through the grouped path below via a subset recursion.  Values
        # are elementwise identical to the grouped evaluation: per-user
        # terms never depend on the grouping, only on the (config, row).
        if whole and Us >= 4096:
            samp = np.arange(0, Us, max(1, Us // 31))
            srows = np.empty((len(samp), 1 + self.L), dtype=np.int32)
            srows[:, 0] = np.where(no_inc[samp], -2, exit_all[samp])
            srows[:, 1:] = place_all[samp]
            sv = np.ascontiguousarray(srows).view(
                np.dtype((np.void, srows.shape[1] * 4))).ravel()
            uniq, counts = np.unique(sv, return_counts=True)
            pj = int(samp[np.nonzero(sv == uniq[np.argmax(counts)])[0][0]])
            pk = int(exit_all[pj])
            if pk >= 0 and solved[pj]:
                pp = place_all[pj]
                neq = exit_all != pk
                for i in range(self.L):
                    neq |= place_all[:, i] != pp[i]
                neq |= no_inc
                idx = np.nonzero(neq)[0]
                if len(idx) * 8 <= Us:
                    nb = self.profile.exits[pk].block + 1
                    place = [int(n) for n in pp[:nb]]
                    cfg = Config(placement=place, final_exit=pk)
                    e_sc, _lat, viol = self._eval_config_users(
                        cfg, self._bw_cols())
                    feas = ~viol
                    energy = np.full(Us, e_sc)
                    if self._mask_count > 0:
                        dead = self._masked[:, place].any(axis=1)
                        feas[dead] = False
                        energy[dead] = np.inf
                    if len(idx):
                        _, sub_f, sub_e = self.evaluate_incumbents(idx)
                        feas[idx] = sub_f
                        energy[idx] = sub_e
                    return no_inc, feas, energy
        # group by incumbent configuration; an injective radix-sortable
        # int64 key (digits = shifted exit/placement columns, base N+2
        # covers the -1 padding) replaces the void-row lexsort whenever the
        # profile is narrow enough to fit — the wide-profile fallback keeps
        # the row view.  No-incumbent users collapse into one skipped
        # sentinel group instead of being filtered up front (saves the
        # index/gather round-trip on the common all-solved tick).
        if (self.L + 1) * int(self.N + 2).bit_length() < 63:
            key = exit_all.astype(np.int64) + 1
            for i in range(self.L):
                key *= self.N + 2
                key += place_all[:, i] + 1
            if any_no:
                key[no_inc] = -1
            _, first, order, bounds = _group_runs(key)
        else:
            rows = np.empty((Us, 1 + self.L), dtype=np.int32)
            rows[:, 0] = np.where(no_inc, -2, exit_all) if any_no \
                else exit_all
            rows[:, 1:] = place_all
            v = np.ascontiguousarray(rows).view(
                np.dtype((np.void, rows.shape[1] * 4))).ravel()
            _, first, order, bounds = _group_runs(v)
        any_mask = self._mask_count > 0
        single = len(first) == 1
        for g, j in enumerate(first):
            j = int(j)
            k = int(exit_all[j])
            if k < 0 or not solved[j]:
                continue                 # the no-incumbent sentinel group
            nb = self.profile.exits[k].block + 1
            place = [int(n) for n in place_all[j, :nb]]
            members = None if single else order[bounds[g]:bounds[g + 1]]
            cfg = Config(placement=place, final_exit=k)
            if members is None:
                gl = users if not whole else None
                bwv = (self._bw_cols() if gl is None
                       else self._bw_rows(gl))
            else:
                gl = users[members] if not whole else members
                bwv = self._bw_rows(gl)
            e_sc, lat, viol = self._eval_config_users(cfg, bwv)
            f = ~viol
            en = np.full(Us if members is None else len(members), e_sc)
            if any_mask:
                rows_m = (self._masked if gl is None
                          else self._masked[gl])
                dead = rows_m[:, place].any(axis=1)
                f[dead] = False
                en[dead] = np.inf
            if members is None:
                feas = f
                energy = en
            else:
                feas[members] = f
                energy[members] = en
        return no_inc, feas, energy

    # ---------------------------------------------------------- checkpointing
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Snapshot the full SoA + cohort-state-table state as a flat dict
        of arrays (the checkpoint leaf set — ``runtime/checkpoint.py``
        saves it verbatim).

        DP grids, candidate caches, fast tables and the exact-energy memo
        are NOT saved: they are deterministic functions of the saved
        (pack, mask) signatures and the proto tensors, so
        :meth:`restore_state` rebuilds them bit-exactly on demand.
        ``state_relaxed`` records WHICH states held relaxed grids so the
        restore re-relaxes exactly those — off-tick probes (contingency
        ``coverage``) and the next tick's ``dp_relaxes`` delta then behave
        identically to the uninterrupted run.
        """
        S = len(self._states)
        M, K2, N = self.M, 2 * self.L - 1, self.N
        pinned = np.zeros(S, dtype=bool)
        if self._pinned:
            pinned[list(self._pinned)] = True
        d = {
            "bw_vec": self._bw_dense().copy(),
            # a user's pack equals their state's stq (the table keys BY
            # pack), so the per-user qpack leaf is a signature-table
            # gather — byte-identical to the historical per-user encode,
            # keeping old and new checkpoints interchangeable
            "qpack": self._stq_enc[self._user_state].reshape(
                self.U, M, K2, N),
            "masked": self._masked.copy(),
            "stale": self._stale.copy(),
            "user_state": self._user_state.copy(),
            "solved": self._solved.copy(),
            "inc_place": self._inc_place.copy(),
            "inc_exit": self._inc_exit.copy(),
            "inc_energy": self._inc_energy.copy(),
            "user_ids": self.user_ids.copy(),
            "quarantined": self._quarantined.copy(),
            "stuck_count": self._stuck_count.copy(),
            "state_stq": (_enc_int16(np.stack([s.stq for s in self._states]))
                          if S else np.zeros((0, M, K2, N), dtype=np.int16)),
            "state_mask": (np.stack([s.mask for s in self._states])
                           if S else np.zeros((0, N), dtype=bool)),
            "state_relaxed": np.array([s.dps is not None
                                       for s in self._states], dtype=bool),
            "state_parent": np.array([s.parent for s in self._states],
                                     dtype=np.int64),
            "state_pinned": pinned,
        }
        if self._last_raw is not None:
            d["last_raw"] = self._last_raw.copy()
        return d

    def restore_state(self, d: Dict[str, np.ndarray]) -> "Population":
        """Restore a :meth:`state_dict` snapshot in place.

        The cohort must match the snapshot (same users and solver
        parameterization), and any structural deltas the snapshot was
        taken under (compute-slice / backhaul repricings — e.g. the
        congestion controller's composed price factors) must be re-applied
        BEFORE restoring, so the proto tensors the rebuilt states scatter
        into equal the snapshot-time ones.  The cohort-state table is
        rebuilt in saved order (state ids are preserved verbatim, so
        ``user_state`` and the pinned set stay valid) and the states that
        held relaxed DP grids are re-relaxed in one launch — bit-exact,
        because the grids are deterministic in (pack, mask, proto
        tensors).
        """
        ids = np.asarray(d["user_ids"], dtype=np.int64)
        if ids.shape != self.user_ids.shape or \
                not np.array_equal(ids, self.user_ids):
            raise ValueError("state_dict user_ids do not match this cohort "
                             f"({ids.shape} vs {self.user_ids.shape})")
        U, N = self.U, self.N
        bw = np.asarray(d["bw_vec"], dtype=np.float64)
        if bw.shape != (U, N):
            raise ValueError(f"bw_vec shape {bw.shape} != ({U}, {N})")
        qp_shape = (U, self.M, 2 * self.L - 1, self.N)
        qp = np.asarray(d["qpack"])
        if qp.shape != qp_shape:
            raise ValueError(f"qpack shape {qp.shape} != {qp_shape}")
        # (the values are redundant — user packs are rebuilt from the
        # saved state table + user_state below; the leaf stays in the
        # checkpoint format for compatibility and shape validation)
        self._bw_vec[:] = bw
        self._bw_lazy = None
        self._masked[:] = d["masked"]
        self._mask_count = int(np.count_nonzero(self._masked))
        self._stale[:] = d["stale"]
        self._solved[:] = d["solved"]
        self._inc_place[:] = d["inc_place"]
        self._inc_exit[:] = d["inc_exit"]
        self._inc_energy[:] = d["inc_energy"]
        self._quarantined[:] = d.get("quarantined", False)
        self._stuck_count[:] = d.get("stuck_count", 0)
        if self._last_raw is not None:
            self._last_raw[:] = d.get("last_raw", np.nan)
        self._solutions = np.full(U, None, dtype=object)
        self._any_solutions = False
        # rebuild the cohort-state table in saved order: every state keys
        # through the same scalar signature encoding, so probes against
        # the restored table return the snapshot-time ids
        self._states = []
        self._state_ids = {}
        self._pinned = set()
        self._cfg_energy = {}
        self._fallback_plan = None
        self._tighten_cache = {}
        self._tighten_base = {}
        self._stq_enc = np.empty((0, self._enc_w), dtype=np.int16)
        stq_all = _dec_int16(np.asarray(d["state_stq"]))
        mask_all = np.asarray(d["state_mask"], dtype=bool)
        parent = np.asarray(d["state_parent"], dtype=np.int64)
        for i in range(len(stq_all)):
            key = self._state_key(stq_all[i], mask_all[i])
            sid = self._add_state(key, stq_all[i].copy(),
                                  mask_all[i].copy(),
                                  parent=int(parent[i]))
            if sid != i:
                raise ValueError(f"duplicate cohort-state signature at "
                                 f"snapshot index {i} (got id {sid})")
        us = np.asarray(d["user_state"], dtype=np.int64)
        if len(us) != U or (len(self._states)
                            and us.max(initial=-1) >= len(self._states)):
            raise ValueError("user_state does not index the saved table")
        self._user_state[:] = us
        self._pinned = {int(s) for s in np.nonzero(
            np.asarray(d["state_pinned"], dtype=bool))[0]}
        relaxed = np.nonzero(np.asarray(d["state_relaxed"],
                                        dtype=bool))[0]
        if len(relaxed):
            self._relax_states([int(s) for s in relaxed], prebuilt=True)
        self._inc_single = self._recompute_inc_single()
        return self

    def _recompute_inc_single(self) -> Optional[Tuple]:
        """One O(U) scan re-deriving the uniform-incumbent flag (used on
        checkpoint restore, where the recording history is gone): set iff
        every user is solved with one identical (exit, placement)."""
        if not bool(self._solved.all()):
            return None
        k = int(self._inc_exit[0])
        if k < 0 or bool((self._inc_exit != k).any()):
            return None
        row0 = self._inc_place[0]
        if bool((self._inc_place != row0[None]).any()):
            return None
        nb = self.profile.exits[k].block + 1
        return (k, tuple(int(n) for n in row0[:nb]))

    def _eval_config_users(self, config: Config, bwv: np.ndarray
                           ) -> Tuple[float, np.ndarray, np.ndarray]:
        """Vectorized ``problem.evaluate_config``: one configuration, many
        users differing only in their source-link bandwidth vector.

        Returns (energy, latency (Us,), violated (Us,)) — the shared
        evaluator now lives in ``core/frontier.py`` (it also powers the
        vectorized frontier post-pass); every per-user result is
        bit-identical to ``evaluate_config`` on that user's mutated
        network.
        """
        e, _ec, _em, lat, viol = eval_config_users(
            self.profile, self.req, self.network0.nodes, self._proto._bw,
            self._proto._compute, self.src, config, bwv,
            check_aggregate_load=self.check_aggregate_load)
        return e, lat, viol
