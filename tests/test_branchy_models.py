"""Branchy CNN tests: Table III fidelity, gating, training step, profiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.branchy import (PAPER_MODELS, TABLE_III_FEATURES, b_alexnet,
                                  b_lenet, b_resnet)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", list(PAPER_MODELS))
def test_block_features_match_table3(name):
    m = PAPER_MODELS[name]()
    shape = m.input_shape
    feats = []
    for blk in m.blocks:
        shape = blk.out_shape(shape)
        feats.append(int(np.prod(shape)))
    assert feats == TABLE_III_FEATURES[name]


@pytest.mark.parametrize("name", list(PAPER_MODELS))
def test_forward_shapes_and_finite(name, key):
    m = PAPER_MODELS[name]()
    params = m.init(key)
    x = jax.random.normal(key, (3,) + m.input_shape)
    logits, feats = m.apply(params, x)
    assert set(logits) == set(m.exit_blocks())
    for v in logits.values():
        assert v.shape == (3, m.n_classes)
        assert bool(jnp.isfinite(v).all())
    assert bool(jnp.isfinite(feats).all())


def test_partial_execution(key):
    """up_to_block truncates the chain — the split-computing primitive."""
    m = b_lenet()
    params = m.init(key)
    x = jax.random.normal(key, (2,) + m.input_shape)
    logits, feats = m.apply(params, x, up_to_block=0)
    assert set(logits) == {0}
    full_logits, _ = m.apply(params, x)
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(full_logits[0]), rtol=1e-6)


def test_gated_inference_thresholds(key):
    """Threshold 0 exits everything at exit-0; threshold >1 never exits early."""
    m = b_lenet()
    params = m.init(key)
    x = jax.random.normal(key, (8,) + m.input_shape)
    _, idx_all_early = m.infer(params, x, [0.0])
    assert (np.asarray(idx_all_early) == 0).all()
    _, idx_never = m.infer(params, x, [1.1])
    assert (np.asarray(idx_never) == len(m.exit_blocks()) - 1).all()


def test_training_step_reduces_loss(key):
    """A few SGD steps on a fixed batch reduce the joint BranchyNet loss."""
    m = b_lenet()
    params = m.init(key)
    x = jax.random.normal(key, (16,) + m.input_shape)
    y = jax.random.randint(key, (16,), 0, m.n_classes)

    loss_fn = jax.jit(lambda p: m.loss(p, x, y))
    grad_fn = jax.jit(jax.grad(lambda p: m.loss(p, x, y)))
    l0 = float(loss_fn(params))
    lr = 1e-2
    for _ in range(10):
        g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    l1 = float(loss_fn(params))
    assert np.isfinite(l0) and np.isfinite(l1)
    assert l1 < l0


def test_extract_profile_feeds_fin(key):
    """The measured profile plugs straight into the placement stack."""
    from repro.core import AppRequirements, solve_fin, solve_opt
    from repro.core.scenarios import paper_scenario

    m = b_lenet()
    prof = m.extract_profile(accuracies=[0.91, 0.97], phis=[0.94, 0.06])
    nw = paper_scenario()
    req = AppRequirements(alpha=0.9, delta=1e-3)
    fin = solve_fin(nw, prof, req, gamma=10)
    opt = solve_opt(nw, prof, req)
    assert fin.feasible and opt.feasible
    assert fin.energy <= opt.energy * 1.1 + 1e-15


def test_resnet_depth_knob(key):
    """blocks_per_stage scales depth (ResNet-110 = 18) without changing shapes."""
    small = b_resnet(blocks_per_stage=1)
    shape = small.input_shape
    feats = []
    for blk in small.blocks:
        shape = blk.out_shape(shape)
        feats.append(int(np.prod(shape)))
    assert feats == TABLE_III_FEATURES["b-resnet"]
    deep = b_resnet(blocks_per_stage=3)
    assert deep.extract_profile().block_ops[1] > \
        small.extract_profile().block_ops[1]
