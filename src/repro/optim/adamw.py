"""AdamW in pure JAX (pytree-native), with optional bf16 state and
gradient-compression hooks for the distributed roofline experiments."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: dict                   # first moment (pytree like params)
    nu: dict                   # second moment


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    #: fp32 moments by default; bf16 halves optimizer memory (jamba/arctic
    #: configs) at the cost of moment precision (TPU stochastic rounding is
    #: the production mitigation; documented in DESIGN.md).
    state_dtype: Optional[str] = None
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None

    def _sdtype(self, p):
        if self.state_dtype is None:
            return jnp.float32
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            self.state_dtype]

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self._sdtype(p))
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[dict, AdamWState]:
        step = state.step + 1
        lr = self.lr if self.schedule is None else self.lr * self.schedule(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mh = m_new / bc1
            vh = v_new / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                    v_new.astype(v.dtype))

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        p_new = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return p_new, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), n


def cosine_schedule(warmup: int, total: int) -> Callable:
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(1, warmup))
        prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
        return warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return fn


# ---------------------------------------------------------------------------
# Gradient compression (distributed-optimization trick; DESIGN.md Sec. 5).
# Applied before the (pseudo-)all-reduce: casting gradients to bf16 halves
# DP collective bytes; int8 with per-tensor scale quarters them.  The
# roofline collective term quantifies the saving (see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

def compress_grads(grads, mode: str):
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    if mode == "int8":
        def q(g):
            gf = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
            return (jnp.round(gf / scale).astype(jnp.int8), scale)
        return jax.tree.map(q, grads)
    raise ValueError(mode)


def decompress_grads(grads, mode: str):
    if mode in ("none", "bf16"):
        return grads
    if mode == "int8":
        return jax.tree.map(
            lambda t: t[0].astype(jnp.float32) * t[1],
            grads, is_leaf=lambda t: isinstance(t, tuple))
    raise ValueError(mode)
