"""Split-serving engine tests: continuous batching, gating, FIN integration."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get
from repro.core import AppRequirements, paper_profile
from repro.core.scenarios import paper_scenario
from repro.models import transformer as T
from repro.runtime.serve_engine import SplitServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get("qwen3-4b", reduced=True)
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_serves_all_requests(setup):
    cfg, params = setup
    eng = SplitServeEngine(cfg, params, batch_size=4, cache_len=64)
    reqs = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(10)]
    stats = eng.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert stats.tokens_out == 10 * 5
    assert all(len(r.tokens) == 5 for r in reqs)


def test_continuous_batching_beats_sequential_steps(setup):
    """10 requests on 4 slots must take far fewer steps than 10 sequential
    prompts (slots are refilled as soon as a sequence finishes)."""
    cfg, params = setup
    eng = SplitServeEngine(cfg, params, batch_size=4, cache_len=128)
    for _ in range(10):
        eng.submit([1, 2, 3], max_new_tokens=4)
    stats = eng.run(max_steps=400)
    sequential_steps = 10 * (3 + 4)
    assert stats.steps < sequential_steps


def test_exit_thresholds_control_depth(setup):
    cfg, params = setup
    # threshold 0: everything exits at the first exit
    eng = SplitServeEngine(cfg, params, batch_size=2, cache_len=32,
                           thresholds=[0.0])
    eng.submit([1, 2], max_new_tokens=4)
    stats = eng.run(max_steps=50)
    assert set(stats.exit_histogram) == {0}
    # threshold > 1: nothing exits early
    eng2 = SplitServeEngine(cfg, params, batch_size=2, cache_len=32,
                            thresholds=[1.1])
    eng2.submit([1, 2], max_new_tokens=4)
    stats2 = eng2.run(max_steps=50)
    assert set(stats2.exit_histogram) == {eng2.n_exits - 1}


def test_fin_placement_energy_accounting(setup):
    cfg, params = setup
    nw = paper_scenario()
    prof = paper_profile("h2")
    req = AppRequirements(alpha=0.5, delta=8e-3)
    eng = SplitServeEngine(cfg, params, batch_size=2, cache_len=64,
                           thresholds=[0.0], network=nw, profile=prof,
                           req=req)
    assert eng.placement is not None
    eng.submit([1, 2], max_new_tokens=6)
    stats = eng.run(max_steps=100)
    assert stats.energy_j > 0
    assert stats.blocks_saved > 0           # exit-0 skips deep blocks
    assert stats.blocks_executed > 0
    # early exits save work: executed < total blocks x tokens
    total = prof.n_blocks * stats.tokens_out
    assert stats.blocks_executed < total


def test_failure_triggers_replacement(setup):
    cfg, params = setup
    nw = paper_scenario()
    prof = paper_profile("h2")
    req = AppRequirements(alpha=0.5, delta=8e-3)
    eng = SplitServeEngine(cfg, params, batch_size=2, cache_len=64,
                           network=nw, profile=prof, req=req)
    before = list(eng.placement.placement)
    used = {p for p in before if p != nw.source_node}
    victim = used.pop() if used else 1
    eng.fail_node(victim)
    assert eng.stats.replacements == 1
    eng.submit([1], max_new_tokens=2)
    stats = eng.run(max_steps=50)
    assert stats.tokens_out == 2


def test_measured_phi_feeds_placement(setup):
    """measured_phi from the gates is a valid phi vector for core.DNNProfile."""
    cfg, params = setup
    eng = SplitServeEngine(cfg, params, batch_size=2, cache_len=64,
                           thresholds=[0.5])
    eng.submit(list(range(1, 5)), max_new_tokens=8)
    stats = eng.run(max_steps=100)
    phi = stats.measured_phi
    assert abs(sum(phi.values()) - 1.0) < 1e-9
