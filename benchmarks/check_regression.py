"""Perf-regression gate: compare fresh bench JSON against a committed
baseline and fail when a tracked ratio metric regresses too far.

Usage:

    python -m benchmarks.check_regression fresh.json \
        [--baseline BENCH_PR4.json] --key speedup --min-ratio 0.8

``--baseline`` defaults to the newest committed ``BENCH_PR<n>.json`` in
the repository root (highest ``<n>``), so CI keeps gating against the
latest committed numbers without a workflow edit per PR.  Rows are
matched by ``name`` across every bench section of both documents (the
``{"benches": {...}}`` format of ``benchmarks.run --json``); only rows
present in BOTH and carrying ``--key`` are compared.  A fresh value below
``min_ratio * baseline`` fails the gate with a per-row report — the CI
smoke job uses it to catch warm-vs-cold speedup regressions of the plan-IR
/ population churn path before they land.

Ratio metrics (speedups) are compared rather than absolute wall-clock so
the gate is robust to machine-speed differences between the baseline host
and the CI runner; ``--min-ratio 0.8`` == "fail on >20% regression".
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path
from typing import Dict, Optional


def _rows(doc: dict) -> Dict[str, dict]:
    """Flatten every bench section by row name.  Malformed rows (not a
    dict, or missing ``name``) are skipped with a named warning rather
    than crashing the gate — a half-written baseline must not mask real
    regressions elsewhere in the document."""
    out: Dict[str, dict] = {}
    for bench, rows in doc.get("benches", {}).items():
        for i, row in enumerate(rows):
            if not isinstance(row, dict) or "name" not in row:
                print(f"warning: skipping malformed row {bench}[{i}] "
                      f"(no 'name' field)", file=sys.stderr)
                continue
            out[row["name"]] = row
    return out


def _num(row: dict, key: str, name: str, which: str) -> Optional[float]:
    """``row[key]`` as a finite float, or None with a named warning when
    the field is missing or non-numeric."""
    if key not in row:
        return None
    try:
        v = float(row[key])
    except (TypeError, ValueError):
        print(f"warning: skipping {name}: {which} {key}="
              f"{row[key]!r} is not numeric", file=sys.stderr)
        return None
    return v


def committed_baselines():
    """Every committed ``BENCH_PR<n>.json`` in the repo root as a sorted
    ``[(n, Path), ...]``.

    Candidates come from ``git ls-files`` so an uncommitted fresh run
    dumped at the repo root cannot silently become its own baseline; when
    git is unavailable (an exported tree) the working-tree glob is the
    fallback."""
    import subprocess
    root = Path(__file__).resolve().parent.parent
    try:
        out = subprocess.run(
            ["git", "ls-files", "BENCH_PR*.json"], cwd=root,
            capture_output=True, text=True, check=True).stdout
        names = [n for n in out.splitlines() if n]
    except (OSError, subprocess.CalledProcessError):
        names = [p.name for p in root.glob("BENCH_PR*.json")]
    found = []
    for name in names:
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", name)
        if m is not None:
            found.append((int(m.group(1)), root / name))
    return sorted(found)


def default_baseline() -> Optional[Path]:
    """Newest committed ``BENCH_PR<n>.json`` (highest n) in the repo
    root (see :func:`committed_baselines`)."""
    found = committed_baselines()
    return found[-1][1] if found else None


def history(key: str, rows_filter: Optional[str] = None) -> int:
    """Per-metric trajectory across every committed ``BENCH_PR<n>.json``:
    one table per row name carrying ``key``, each line a PR's value and
    its delta vs the previous PR that had the row.  Rows or metrics
    missing from a PR print as gaps (the benches grew over time), and
    unreadable documents warn-and-skip — history must render even when an
    old baseline predates a row's introduction."""
    files = committed_baselines()
    docs = []
    for n, path in files:
        try:
            with open(path) as f:
                docs.append((n, _rows(json.load(f))))
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {path.name}: {e}", file=sys.stderr)
    if not docs:
        print("error: no committed BENCH_PR<n>.json found",
              file=sys.stderr)
        return 2
    names = sorted({name for _, rs in docs for name, row in rs.items()
                    if key in row})
    if rows_filter is not None:
        names = [n for n in names if rows_filter in n]
    if not names:
        print(f"error: no rows with key {key!r} in any committed "
              f"baseline", file=sys.stderr)
        return 2
    for name in names:
        print(f"\n{name} · {key}")
        prev = None
        for n, rs in docs:
            row = rs.get(name)
            if row is None or key not in row:
                print(f"  PR{n:<3} --")
                continue
            v = _num(row, key, name, f"PR{n}")
            if v is None:
                continue       # non-numeric: warned by _num, keep prev
            delta = ("" if prev in (None, 0.0)
                     else f"  ({(v - prev) / prev * 100.0:+.1f}%)")
            print(f"  PR{n:<3} {v:<12.6g}{delta}")
            prev = v
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="?", default=None,
                    help="fresh benchmarks.run --json output "
                         "(omit with --history)")
    ap.add_argument("--history", action="store_true",
                    help="print the --key trajectory across every "
                         "committed BENCH_PR<n>.json instead of gating")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON (e.g. BENCH_PR4.json); "
                         "default: the newest committed BENCH_PR<n>.json")
    ap.add_argument("--key", default="speedup",
                    help="ratio metric to gate on (default: speedup)")
    ap.add_argument("--min-ratio", type=float, default=0.8,
                    help="fail when fresh < min_ratio * baseline "
                         "(default 0.8 == >20%% regression)")
    ap.add_argument("--rows", default=None,
                    help="only gate rows whose name contains this "
                         "substring (e.g. channel_ for the stable "
                         "warm-vs-cold rows; microbench rows are noisier)")
    ap.add_argument("--invert", action="store_true",
                    help="gate a smaller-is-better metric (e.g. init_s): "
                         "the ratio becomes baseline/fresh, so "
                         "--min-ratio 5.0 means 'fresh must be >=5x "
                         "smaller than baseline'")
    args = ap.parse_args()

    if args.history:
        return history(args.key, args.rows)
    if args.fresh is None:
        ap.error("fresh is required unless --history is given")

    baseline = args.baseline
    if baseline is None:
        found = default_baseline()
        if found is None:
            print("error: no committed BENCH_*.json baseline found and "
                  "no --baseline given", file=sys.stderr)
            return 2
        baseline = str(found)
        print(f"baseline: {found.name} (newest committed)")
    if Path(args.fresh).resolve() == Path(baseline).resolve():
        # a fresh run saved over the newest BENCH_PR<n>.json would gate
        # against itself (every ratio exactly 1.0) — refuse loudly
        print(f"error: fresh output and baseline are the same file "
              f"({baseline}); write the fresh run outside the repo root "
              f"or pass --baseline explicitly", file=sys.stderr)
        return 2

    with open(args.fresh) as f:
        fresh = _rows(json.load(f))
    with open(baseline) as f:
        base = _rows(json.load(f))

    compared = 0
    failures = []
    for name, brow in sorted(base.items()):
        if args.rows is not None and args.rows not in name:
            continue
        if args.key not in brow or name not in fresh:
            continue
        b = _num(brow, args.key, name, "baseline")
        if b is None:
            continue          # non-numeric baseline: warned and skipped
        frow = fresh[name]
        if args.key not in frow:
            print(f"warning: skipping {name}: baseline has "
                  f"{args.key}={b:.3g} but the fresh run dropped the "
                  f"metric", file=sys.stderr)
            continue
        f_ = _num(frow, args.key, name, "fresh")
        if f_ is None:
            continue
        compared += 1
        if args.invert:
            ratio = b / f_ if f_ else float("inf")
        else:
            ratio = f_ / b if b else float("inf")
        status = "OK " if ratio >= args.min_ratio else "FAIL"
        print(f"{status} {name}: {args.key} {f_:.3f} vs baseline {b:.3f} "
              f"(ratio {ratio:.2f}, floor {args.min_ratio:.2f})")
        if ratio < args.min_ratio:
            failures.append(f"{name}: {args.key} regressed to {f_:.3f} "
                            f"from {b:.3f} ({(1 - ratio) * 100:.0f}%)")
    if not compared:
        print(f"error: no rows with key {args.key!r} shared between "
              f"{args.fresh} and {baseline}", file=sys.stderr)
        return 2
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"\n{compared} row(s) within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
