"""Quickstart: the paper in 60 seconds.

1. Build the mobile-edge-cloud system and a branchy DNN profile (B-AlexNet).
2. Solve the placement with FIN, MCP, and exhaustive Opt.
3. Compare energy / latency / accuracy and show the chosen split.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.core import (AppRequirements, paper_profile, solve_fin, solve_mcp,
                        solve_opt)
from repro.core.scenarios import paper_scenario


def main() -> int:
    network = paper_scenario()
    profile = paper_profile("h2")          # B-AlexNet / CIFAR10 (Table II)
    req = AppRequirements(alpha=0.80, delta=5e-3, sigma=1.0)

    print(f"system : {[n.name for n in network.nodes]}")
    print(f"model  : {profile.name} ({profile.n_blocks} blocks, "
          f"{profile.n_exits} exits)")
    print(f"target : accuracy >= {req.alpha:.0%}, latency <= "
          f"{req.delta*1e3:g} ms\n")

    tiers = [n.tier for n in network.nodes]
    for name, solver, kwargs in (("FIN(g=10)", solve_fin, dict(gamma=10)),
                                 ("MCP", solve_mcp, {}),
                                 ("Opt", solve_opt, {})):
        sol = solver(network, profile, req, **kwargs)
        if not sol.found:
            print(f"{name:10s} -> no configuration found")
            continue
        ev = sol.eval
        place = " -> ".join(
            f"l{i+1}@{tiers[n]}" for i, n in
            enumerate(sol.config.placement))
        flag = "" if ev.feasible else "  [INFEASIBLE]"
        print(f"{name:10s} energy {ev.energy*1e3:7.3f} mJ | latency "
              f"{ev.latency*1e3:6.3f} ms | acc {ev.accuracy:.1%} | "
              f"exit-{sol.config.final_exit + 1}{flag}")
        print(f"{'':10s} {place}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
