"""Split-serving engine: exit-aware continuous batching over a FIN placement.

This is the TPU-native adaptation of the paper's execution model
(DESIGN.md Sec. 3): SPMD cannot stop computing individual batch lanes, so
per-sample early exits are realized as *scheduling*:

  * every decode step runs the full stack once for the active batch;
  * the fused gate (kernels/ee_gate) scores each exit's logits; a sequence
    whose confidence clears its threshold takes THAT exit's token — deeper
    blocks' output for it is discarded;
  * finished sequences free their slot immediately and the next queued
    request takes it (continuous batching) — phi-fraction compute saving
    becomes throughput;
  * per-token *tier accounting*: with a FIN placement (blocks -> tiers),
    the engine charges each token only the blocks up to its exit, yielding
    the measured energy the paper's objective (3a) predicts;
  * fault tolerance: the placement lives in a persistent ``core.Plan`` —
    ``fail_node`` masks the dead node and issues a *warm* re-solve (no
    graph reconstruction; bit-exact vs a cold solve on the reduced
    network), ``recover_node`` unmasks and re-solves; node indices stay
    stable across failures (Sec. V elasticity).  Every failover re-split
    also exposes the scenario's Pareto frontier (``engine.frontier``,
    core/frontier.py), and with ``migration_weight > 0`` the re-split is
    frontier-aware: the engine deploys the frontier row minimizing
    ``energy + migration_weight * migration_bits`` — on recovery that can
    keep the current placement instead of migrating everything back for a
    marginal energy win.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (AppRequirements, Config, DNNProfile, Network,
                        ParetoFrontier, Plan, evaluate_config,
                        migration_delta)
from repro.core.frontier import frontier_pick
from repro.kernels.ee_gate.ops import ee_gate
from repro.models import transformer as T


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    tokens: List[int] = field(default_factory=list)
    exits_taken: List[int] = field(default_factory=list)  # exit idx per token
    done: bool = False


@dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    exit_histogram: Dict[int, int] = field(default_factory=dict)
    blocks_executed: int = 0          # tier-charged block executions
    blocks_saved: int = 0             # skipped by early exits
    energy_j: float = 0.0             # placement-model energy (Eq. 2 units)
    replacements: int = 0             # FIN re-solves after failures/recovery
    blocks_migrated: int = 0          # blocks re-hosted by re-placements
    migration_bits: float = 0.0       # state bits moved by re-placements

    @property
    def measured_phi(self) -> Dict[int, float]:
        tot = max(1, sum(self.exit_histogram.values()))
        return {k: v / tot for k, v in sorted(self.exit_histogram.items())}


class SplitServeEngine:
    """Decode engine with exit-aware continuous batching.

    Prompts are consumed token-by-token through the decode path (prefill-as-
    decode keeps slot cache surgery trivial); generation then proceeds with
    gated exits.  ``placement``/``profile``/``network`` wire the engine to
    the paper's placement problem for energy accounting; they are optional —
    without them the engine is a plain continuous-batching server.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int,
                 cache_len: int, thresholds: Optional[Sequence[float]] = None,
                 network: Optional[Network] = None,
                 profile: Optional[DNNProfile] = None,
                 req: Optional[AppRequirements] = None,
                 gamma: int = 10, seed: int = 0,
                 migration_weight: float = 0.0, frontier_k: int = 4):
        assert cfg.has_decoder
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.n_exits = len(cfg.exit_layer_list) + 1
        self.thresholds = list(thresholds) if thresholds is not None else \
            [0.9] * (self.n_exits - 1)
        self.caches = T.init_caches(cfg, batch_size, cache_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, t, c, pos))
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.stats = EngineStats()
        self.pos = 0
        self._slot_len = np.zeros(batch_size, np.int32)
        # placement integration: a persistent Plan owns the built pipeline
        # state, so failure/recovery re-solves are warm deltas
        self.profile = profile
        self.app_req = req
        self.gamma = gamma
        self.plan: Optional[Plan] = None
        self.placement: Optional[Config] = None
        self.network = network
        if migration_weight < 0:
            raise ValueError(f"migration_weight must be >= 0, got "
                             f"{migration_weight}")
        if frontier_k < 1:
            raise ValueError(f"frontier_k must be >= 1, got {frontier_k}")
        self.migration_weight = float(migration_weight)
        self.frontier_k = int(frontier_k)
        #: the Pareto frontier of the last (re-)placement — refreshed on
        #: every failover / recovery re-split (core/frontier.py)
        self.frontier: Optional[ParetoFrontier] = None
        if network is not None and profile is not None and req is not None:
            self.plan = Plan(network, profile, req, gamma=gamma)
            sol = self.plan.solve()
            assert sol.feasible, "no feasible FIN placement"
            self.placement = sol.config
            self.frontier = self.plan.frontier(k_per_exit=self.frontier_k)
            self.network = self.plan.network   # live view of current state

    # ------------------------------------------------------------------ API
    def submit(self, prompt: Sequence[int], max_new_tokens: int) -> Request:
        r = Request(rid=len(self.queue) + 10_000, prompt=list(prompt),
                    max_new_tokens=max_new_tokens)
        self.queue.append(r)
        return r

    def fail_node(self, node_idx: int) -> None:
        """Node failure: mask the node in the plan and warm re-solve.

        The plan keeps its node indexing (the placement simply avoids the
        dead node), so tier accounting and any in-flight references stay
        valid; the re-solve reuses the cached pipeline state and is
        bit-exact vs a cold solve on the reduced network."""
        assert self.plan is not None
        self.plan.mask_node(node_idx)
        self._replace()

    def recover_node(self, node_idx: int) -> None:
        """Node recovery: unmask and warm re-solve (may migrate back)."""
        assert self.plan is not None
        self.plan.unmask_node(node_idx)
        self._replace()

    def _replace(self) -> None:
        """Warm re-solve + frontier-aware re-split.

        The plan's Pareto frontier is exposed on every re-split
        (``self.frontier``); with ``migration_weight > 0`` the new
        placement is the option minimizing ``energy + migration_weight *
        migration_bits`` over the frontier rows AND the current placement
        (if it is still feasible — after a recovery, keeping the current
        hosts avoids migrating every block back for a marginal win).
        ``migration_weight=0`` deploys the argmin row, the pre-frontier
        behaviour."""
        old = self.placement
        sol = self.plan.solve()
        fr = self.plan.frontier(k_per_exit=self.frontier_k)
        self.frontier = fr
        choice = sol.config
        if self.migration_weight > 0 and old is not None:
            ev_old = self.plan.evaluate(old)
            choice, _energy, _moved, _bits, _kept = frontier_pick(
                fr, old, ev_old.feasible, ev_old.energy, self.profile,
                self.migration_weight)
            if choice is not None and (
                    not sol.feasible
                    or choice.placement != sol.config.placement
                    or choice.final_exit != sol.config.final_exit):
                self.plan.adopt(choice)     # a non-argmin frontier choice
        if choice is None:
            raise RuntimeError("no feasible placement after failure")
        self.placement = choice
        self.stats.replacements += 1
        moved, bits = migration_delta(self.profile, old, choice)
        self.stats.blocks_migrated += moved
        self.stats.migration_bits += bits

    def run(self, *, max_steps: int = 10_000) -> EngineStats:
        while (any(self.slots) or self.queue) and self.stats.steps < max_steps:
            self.step()
        return self.stats

    # ----------------------------------------------------------------- step
    def _fill_slots(self) -> None:
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                self.slots[i] = self.queue.pop(0)
                self._slot_len[i] = 0

    def _charge(self, exit_idx: int) -> None:
        """Tier accounting for one emitted token at the given exit."""
        st = self.stats
        st.exit_histogram[exit_idx] = st.exit_histogram.get(exit_idx, 0) + 1
        if self.profile is None or self.placement is None:
            return
        prof, place = self.profile, self.placement
        last_block = prof.exits[min(exit_idx, prof.n_exits - 1)].block
        nw = self.network
        for b in range(prof.n_blocks):
            if b <= last_block:
                st.blocks_executed += 1
                n = place.placement[min(b, len(place.placement) - 1)]
                t_comp = prof.block_ops_with_exit(b, prof.n_exits - 1) \
                    / nw.compute[n]
                st.energy_j += nw.power_active[n] * t_comp
                if b < last_block:
                    n2 = place.placement[min(b + 1, len(place.placement) - 1)]
                    if n2 != n:
                        st.energy_j += (nw.e_tx[n] + nw.e_rx[n2]) \
                            * prof.cut_bits[b]
            else:
                st.blocks_saved += 1

    def step(self) -> None:
        self._fill_slots()
        if not any(self.slots):
            return
        toks = np.zeros((self.B, 1), np.int32)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            consumed = int(self._slot_len[i])
            if consumed < len(r.prompt):
                toks[i, 0] = r.prompt[consumed]
            else:
                toks[i, 0] = r.tokens[-1] if r.tokens else r.prompt[-1]

        logits, self.caches, exits = self._decode(
            self.params, self.caches, jnp.asarray(toks),
            jnp.int32(self.pos))
        self.pos += 1
        self.stats.steps += 1

        # gate every exit with the fused kernel; first-exit-wins
        confs, args = [], []
        for j, p_idx in enumerate(self.cfg.exit_layer_list):
            c, a = ee_gate(exits[f"exit_{p_idx}"])
            confs.append(np.asarray(c))
            args.append(np.asarray(a))
        c_f, a_f = ee_gate(logits)
        confs.append(np.asarray(c_f))
        args.append(np.asarray(a_f))

        for i, r in enumerate(self.slots):
            if r is None:
                continue
            self._slot_len[i] += 1
            if self._slot_len[i] < len(r.prompt):
                continue  # still consuming the prompt
            exit_idx = self.n_exits - 1
            for j in range(self.n_exits - 1):
                if confs[j][i] >= self.thresholds[j]:
                    exit_idx = j
                    break
            token = int(args[exit_idx][i])
            r.tokens.append(token)
            r.exits_taken.append(exit_idx)
            self.stats.tokens_out += 1
            self._charge(exit_idx)
            if len(r.tokens) >= r.max_new_tokens:
                r.done = True
                self.slots[i] = None   # continuous batching: free the slot
