"""Minimal functional CNN layer library (pure JAX) with MAC accounting.

Each layer is a dataclass with:
  init(key, in_shape)   -> (params, out_shape)
  apply(params, x)      -> y                    (x: [B, H, W, C] or [B, F])
  macs(in_shape)        -> multiply-accumulates per sample
The MAC counts feed ``profile_from_model`` (dnn_profile extraction), closing
the loop between the JAX models and the placement problem's Plane 2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Shape = Tuple[int, ...]


def _he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * math.sqrt(2.0 / fan_in)


@dataclass(frozen=True)
class Conv:
    features: int
    kernel: int
    stride: int = 1
    padding: str = "SAME"          # "SAME" | "VALID"
    use_relu: bool = True

    def out_shape(self, in_shape: Shape) -> Shape:
        h, w, c = in_shape
        if self.padding == "SAME":
            oh = -(-h // self.stride)
            ow = -(-w // self.stride)
        else:
            oh = (h - self.kernel) // self.stride + 1
            ow = (w - self.kernel) // self.stride + 1
        return (oh, ow, self.features)

    def init(self, key, in_shape: Shape):
        c = in_shape[-1]
        fan_in = self.kernel * self.kernel * c
        w = _he_init(key, (self.kernel, self.kernel, c, self.features), fan_in)
        b = jnp.zeros((self.features,))
        return {"w": w, "b": b}, self.out_shape(in_shape)

    def apply(self, params, x):
        y = jax.lax.conv_general_dilated(
            x, params["w"],
            window_strides=(self.stride, self.stride),
            padding=self.padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        y = y + params["b"]
        return jax.nn.relu(y) if self.use_relu else y

    def macs(self, in_shape: Shape) -> float:
        oh, ow, _ = self.out_shape(in_shape)
        c = in_shape[-1]
        return float(self.kernel * self.kernel * c * self.features * oh * ow)


@dataclass(frozen=True)
class MaxPool:
    window: int
    stride: int

    def out_shape(self, in_shape: Shape) -> Shape:
        h, w, c = in_shape
        oh = (h - self.window) // self.stride + 1
        ow = (w - self.window) // self.stride + 1
        return (oh, ow, c)

    def init(self, key, in_shape: Shape):
        return {}, self.out_shape(in_shape)

    def apply(self, params, x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, self.window, self.window, 1),
            (1, self.stride, self.stride, 1), "VALID")

    def macs(self, in_shape: Shape) -> float:
        return 0.0


@dataclass(frozen=True)
class GlobalAvgPool:
    def out_shape(self, in_shape: Shape) -> Shape:
        return (in_shape[-1],)

    def init(self, key, in_shape: Shape):
        return {}, self.out_shape(in_shape)

    def apply(self, params, x):
        return x.mean(axis=(1, 2))

    def macs(self, in_shape: Shape) -> float:
        return 0.0


@dataclass(frozen=True)
class Flatten:
    def out_shape(self, in_shape: Shape) -> Shape:
        return (int(np.prod(in_shape)),)

    def init(self, key, in_shape: Shape):
        return {}, self.out_shape(in_shape)

    def apply(self, params, x):
        return x.reshape(x.shape[0], -1)

    def macs(self, in_shape: Shape) -> float:
        return 0.0


@dataclass(frozen=True)
class Dense:
    features: int
    use_relu: bool = False

    def out_shape(self, in_shape: Shape) -> Shape:
        return (self.features,)

    def init(self, key, in_shape: Shape):
        fan_in = int(np.prod(in_shape))
        w = _he_init(key, (fan_in, self.features), fan_in)
        b = jnp.zeros((self.features,))
        return {"w": w, "b": b}, (self.features,)

    def apply(self, params, x):
        y = x.reshape(x.shape[0], -1) @ params["w"] + params["b"]
        return jax.nn.relu(y) if self.use_relu else y

    def macs(self, in_shape: Shape) -> float:
        return float(np.prod(in_shape)) * self.features


@dataclass(frozen=True)
class Residual:
    """Basic 2-conv residual block (ResNet CIFAR style)."""
    features: int
    stride: int = 1

    def _convs(self):
        return (Conv(self.features, 3, self.stride, "SAME", use_relu=True),
                Conv(self.features, 3, 1, "SAME", use_relu=False))

    def out_shape(self, in_shape: Shape) -> Shape:
        c1, c2 = self._convs()
        return c2.out_shape(c1.out_shape(in_shape))

    def init(self, key, in_shape: Shape):
        k1, k2, k3 = jax.random.split(key, 3)
        c1, c2 = self._convs()
        p1, s1 = c1.init(k1, in_shape)
        p2, s2 = c2.init(k2, s1)
        params = {"c1": p1, "c2": p2}
        if in_shape[-1] != self.features or self.stride != 1:
            proj = Conv(self.features, 1, self.stride, "SAME", use_relu=False)
            params["proj"], _ = proj.init(k3, in_shape)
        return params, s2

    def apply(self, params, x):
        c1, c2 = self._convs()
        y = c1.apply(params["c1"], x)
        y = c2.apply(params["c2"], y)
        if "proj" in params:
            proj = Conv(self.features, 1, self.stride, "SAME", use_relu=False)
            x = proj.apply(params["proj"], x)
        return jax.nn.relu(x + y)

    def macs(self, in_shape: Shape) -> float:
        c1, c2 = self._convs()
        m = c1.macs(in_shape)
        s1 = c1.out_shape(in_shape)
        m += c2.macs(s1)
        if in_shape[-1] != self.features or self.stride != 1:
            m += Conv(self.features, 1, self.stride).macs(in_shape)
        return m


# ---------------------------------------------------------------------------
# Sequential container
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Sequential:
    layers: Tuple

    def init(self, key, in_shape: Shape):
        params = []
        shape = in_shape
        keys = jax.random.split(key, max(1, len(self.layers)))
        for lyr, k in zip(self.layers, keys):
            p, shape = lyr.init(k, shape)
            params.append(p)
        return params, shape

    def apply(self, params, x):
        for lyr, p in zip(self.layers, params):
            x = lyr.apply(p, x)
        return x

    def out_shape(self, in_shape: Shape) -> Shape:
        shape = in_shape
        for lyr in self.layers:
            shape = lyr.out_shape(shape)
        return shape

    def macs(self, in_shape: Shape) -> float:
        total = 0.0
        shape = in_shape
        for lyr in self.layers:
            total += lyr.macs(shape)
            shape = lyr.out_shape(shape)
        return total
