"""Benchmark harness — one bench per paper table/figure + system benches.

Usage:  PYTHONPATH=src python -m benchmarks.run [--only substr] [--json]
                                                [--smoke]

Prints ``name,us_per_call,derived`` CSV rows (one per measurement) or, with
``--json``, a JSON document ``{"benches": {<bench>: [row...]}}`` with the
derived key-values parsed (the format of the committed BENCH_PR2.json).
``--smoke`` shrinks instance sizes / repeats (REPRO_BENCH_SMOKE=1) for the
CI perf-regression smoke job.  Benches match the paper artifacts:
  fig4      Table VI configuration study (latency / energy / accuracy)
  fig5_7    Opt vs MCP vs FIN(3,10) energy across (delta, alpha) targets
  fig6      computation/communication energy breakdown
  fig8      multi-application scenario (gain, tiers, failures, exits)
  table3    DNN block profiles extracted from the JAX models vs paper
  table7    solver execution times (+ large-instance scaling backends)
  online    warm plan-IR re-solves vs cold rebuilds under churn (+ e2e
            orchestrator throughput with hysteresis and failures)
  congestion shared-capacity coupled ticks: converged-tick throughput,
            fixed-point iterations and admission rate vs the uncoupled
            population path on self-calibrated over-subscription
  failover  contingency-library hits vs warm mask+re-solve vs cold rebuild
            (bit-exact, zero-relaxation), + tier-outage trace hit rate
  faults    crash consistency: boundary-checkpoint overhead (asserted
            bit-identical to the uncheckpointed run), cold restore+replay
            latency, and quarantine-policy throughput under injected
            telemetry corruption
  stream    streaming tick pipeline: double-buffered ticks vs the sync
            loop, fused vs chunked newborn relax, bounded re-relaxation
            (all asserted bit-exact), + 1e6/1e7-user scale rows
  kernels   Pallas kernel vs reference oracle timings (interpret mode)
  roofline  dry-run derived roofline terms per (arch x shape)
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import traceback

BENCHES = [
    "bench_fig4",
    "bench_fig5_7",
    "bench_fig6",
    "bench_fig8",
    "bench_table3",
    "bench_table7",
    "bench_online",
    "bench_congestion",
    "bench_failover",
    "bench_faults",
    "bench_stream",
    "bench_kernels",
    "bench_engine",
    "bench_roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run only benches whose name contains this substring")
    ap.add_argument("--json", action="store_true",
                    help="emit a JSON document with parsed derived key-values"
                         " instead of CSV rows")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes/repeats (sets REPRO_BENCH_SMOKE=1) — "
                         "the CI perf smoke mode")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    if not args.json:
        print("name,us_per_call,derived")
    collected = {}
    failures = []
    for mod_name in BENCHES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ModuleNotFoundError as e:
            failures.append((mod_name, f"missing: {e}"))
            continue
        try:
            for row in mod.run():
                if args.json:
                    collected.setdefault(mod_name.replace("bench_", ""),
                                         []).append(row.to_dict())
                else:
                    print(row.csv())
                    sys.stdout.flush()
        except Exception:
            failures.append((mod_name, traceback.format_exc()))
    if args.json:
        print(json.dumps({"smoke": bool(args.smoke), "benches": collected},
                         indent=1))
    if failures:
        for name, err in failures:
            print(f"# BENCH-FAILED {name}: {err.splitlines()[-1]}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
