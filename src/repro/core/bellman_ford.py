"""(min,+) relaxation primitives backing FIN's minimum-cost traversal.

FIN's feasible graph is a layered DAG over states s = (node, depth); the
minimum-cost traversal is a sequence of (min,+) ("tropical") matrix-vector
products — exactly a Bellman-Ford relaxation restricted to the layer
structure.  Three backends:

  * numpy  — reference / small instances, with argmin backtracking;
  * jnp    — jitted dense relaxation for large instances (scaling benches);
  * pallas — the ``minplus`` TPU kernel (kernels/minplus), VMEM-tiled.

The paper reports solver wall-time (Table VII), so this *is* a hot spot the
paper measures; on TPU the relaxation maps naturally onto the VPU with
(min,+) in place of (+,*) — see kernels/minplus/minplus.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# numpy reference
# ---------------------------------------------------------------------------

def minplus_vecmat_np(dist: np.ndarray, W: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """out[t] = min_s dist[s] + W[s, t]; returns (out, argmin_s)."""
    cand = dist[:, None] + W                     # (S, T)
    arg = np.argmin(cand, axis=0)
    out = cand[arg, np.arange(W.shape[1])]
    return out, arg


def bellman_ford_np(W: np.ndarray, src: int, *, max_iters: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Classic dense Bellman-Ford on an (S, S) weight matrix (inf = no edge).

    Returns (dist, parent).  Used to cross-validate the layered DP and to
    solve non-layered instances (e.g. MCP on general meshes).
    """
    S = W.shape[0]
    dist = np.full(S, np.inf)
    parent = np.full(S, -1, dtype=np.int64)
    dist[src] = 0.0
    iters = max_iters if max_iters is not None else S - 1
    for _ in range(iters):
        new, arg = minplus_vecmat_np(dist, W)
        improved = new < dist - 1e-18
        if not improved.any():
            break
        parent[improved] = arg[improved]
        dist = np.where(improved, new, dist)
    return dist, parent


# ---------------------------------------------------------------------------
# jnp (jit) backend
# ---------------------------------------------------------------------------

@jax.jit
def minplus_vecmat_jnp(dist: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """out[t] = min_s dist[s] + W[s, t] (cost only, differentiable-free)."""
    return jnp.min(dist[:, None] + W, axis=0)


@jax.jit
def minplus_matmat_jnp(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Tropical matmul: out[i, j] = min_k A[i, k] + B[k, j].

    This is the batched form used when relaxing many sources at once
    (multi-application orchestration relaxes one row per user).
    """
    return jnp.min(A[:, :, None] + B[None, :, :], axis=1)


def layered_relax_jnp(init: jnp.ndarray, Ws: jnp.ndarray) -> jnp.ndarray:
    """Relax through a stack of layer transition matrices via lax.scan.

    init: (S,) initial distances; Ws: (L, S, S).  Returns (L+1, S) distances
    after each layer.  jit-compiled once per (S, L) shape.
    """
    def step(dist, W):
        new = minplus_vecmat_jnp(dist, W)
        return new, new

    _, hist = jax.lax.scan(step, init, Ws)
    return jnp.concatenate([init[None], hist], axis=0)


def layered_relax(init: np.ndarray, Ws: np.ndarray, backend: str = "numpy",
                  ) -> np.ndarray:
    """Dispatch layered relaxation to a backend. Returns (L+1, S) distances."""
    if backend == "numpy":
        out = [init]
        d = init
        for W in Ws:
            d, _ = minplus_vecmat_np(d, W)
            out.append(d)
        return np.stack(out)
    if backend == "jnp":
        return np.asarray(layered_relax_jnp(jnp.asarray(init), jnp.asarray(Ws)))
    if backend == "pallas":
        from repro.kernels.minplus.ops import minplus_vecmat as mp_pallas
        out = [init]
        d = jnp.asarray(init, jnp.float32)
        for W in Ws:
            d = mp_pallas(d[None, :], jnp.asarray(W, jnp.float32))[0]
            out.append(np.asarray(d))
        return np.stack(out)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# argmin-tracking relaxation (exact path reconstruction for the FIN DP)
# ---------------------------------------------------------------------------

def layered_relax_argmin(init: np.ndarray, Ws: np.ndarray,
                         backend: str = "numpy"
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Layered relaxation with parent recovery.

    init: (S,), Ws: (L, S, S).  Returns (dist, parent) where dist is (L+1, S)
    distances after each layer and parent is (L, S): parent[l, t] is the
    argmin source state in layer l for state t in layer l+1, or -1 where t is
    unreached.  Single-scenario view of ``batched_layered_relax_argmin``
    (which see for the backend contract); the pallas backend runs the
    ``minplus`` argmin kernel layer by layer.
    """
    hist, par = batched_layered_relax_argmin(np.asarray(init)[None],
                                             np.asarray(Ws)[None],
                                             backend=backend)
    return hist[0], par[0]


def batched_layered_relax_argmin(init: np.ndarray, Ws: np.ndarray,
                                 backend: str = "numpy"
                                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched layered relaxation with parents: one (min,+) chain per scenario.

    init: (B, S); Ws: (B, L, S, S).  Returns (dist (B, L+1, S), parent
    (B, L, S)); parent is -1 where the target state is unreachable.  Backends:
    ``numpy`` (vectorized over the whole batch per layer), ``jnp`` (one
    lax.scan over layers, batch in the leading dim), ``pallas`` (argmin
    kernel, looped per scenario — per-scenario W defeats the shared-W kernel
    batching; block-diagonal matmat batching is the TPU follow-up).
    """
    B, S = init.shape
    L = Ws.shape[1]
    if L == 0:                       # single-block chain: no transitions
        return (np.asarray(init)[:, None, :].astype(np.float64),
                np.zeros((B, 0, S), dtype=np.int64))
    if backend == "numpy":
        dist = init
        hist = [dist]
        pars = []
        cand = np.empty((B, S, S), dtype=np.float64)   # reused across layers
        for l in range(L):
            np.add(dist[:, :, None], Ws[:, l], out=cand)     # (B, S, T)
            arg = np.argmin(cand, axis=1)
            new = np.take_along_axis(cand, arg[:, None, :], axis=1)[:, 0, :]
            pars.append(np.where(np.isfinite(new), arg, -1))
            hist.append(new)
            dist = new
        return np.stack(hist, axis=1), np.stack(pars, axis=1).astype(np.int64)
    if backend == "jnp":
        def step(d, W):
            cand = d[:, :, None] + W                         # (B, S, T)
            new = jnp.min(cand, axis=1)
            arg = jnp.argmin(cand, axis=1)
            return new, (new, jnp.where(jnp.isfinite(new), arg, -1))
        _, (h, p) = jax.lax.scan(step, jnp.asarray(init),
                                 jnp.moveaxis(jnp.asarray(Ws), 1, 0))
        hist = np.concatenate([np.asarray(init)[:, None],
                               np.moveaxis(np.asarray(h), 0, 1)], axis=1)
        return hist, np.moveaxis(np.asarray(p), 0, 1).astype(np.int64)
    if backend == "pallas":
        from repro.kernels.minplus.ops import minplus_vecmat_argmin
        hists, pars = [], []
        for b in range(B):
            d = jnp.asarray(init[b], jnp.float32)
            hist = [np.asarray(init[b])]
            par = []
            for W in Ws[b]:
                out, arg = minplus_vecmat_argmin(
                    d[None, :], jnp.asarray(W, jnp.float32))
                d = out[0]
                hist.append(np.asarray(d, np.float64))
                par.append(np.asarray(arg[0], np.int64))
            hists.append(np.stack(hist))
            pars.append(np.stack(par))
        return np.stack(hists), np.stack(pars)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# k-best relaxation (beyond-paper quantizer-collision fix, vectorized)
# ---------------------------------------------------------------------------

def batched_layered_relax_min(init: np.ndarray, Ws: np.ndarray) -> np.ndarray:
    """Batched layered relaxation, distances only (numpy).

    init: (B, S); Ws: (B, L, S, S).  Returns dist (B, L+1, S).  The parent
    tensor is deliberately NOT computed: callers that need path
    reconstruction recover a parent with one argmin column scan per
    backtracked step (see fin._FlatDP) — orders of magnitude fewer argmins
    than materializing (B, L, S) parents when only a handful of end states
    are ever traced back.
    """
    B, S = init.shape
    L = Ws.shape[1]
    if L == 0:
        return np.asarray(init)[:, None, :].astype(np.float64)
    dist = init
    hist = [dist]
    cand = np.empty((B, S, S), dtype=np.float64)   # reused across layers
    for l in range(L):
        np.add(dist[:, :, None], Ws[:, l], out=cand)
        dist = np.min(cand, axis=1)
        hist.append(dist)
    return np.stack(hist, axis=1)


def batched_layered_relax_kbest(init: np.ndarray, Ws: np.ndarray, K: int
                                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Keep the K cheapest paths per state while relaxing layer by layer.

    init: (B, S); Ws: (B, L, S, S).  Returns (dist (B, L+1, S, K), par_s,
    par_k (B, L, S, K)) — the k-th cheapest distance at each state with the
    (source state, source rank) that produced it (-1 where unused).  Each
    layer sorts the S*K candidate pool per target with a stable argsort, so
    tie order is deterministic (source-state-major).  numpy only: K > 1 is
    the beyond-paper small-gamma mode and stays far from the hot path.
    """
    B, S = init.shape
    L = Ws.shape[1]
    dist = np.full((B, S, K), np.inf)
    dist[:, :, 0] = init
    if L == 0:
        return (dist[:, None], np.zeros((B, 0, S, K), dtype=np.int64),
                np.zeros((B, 0, S, K), dtype=np.int64))
    hist = [dist]
    ps, pk = [], []
    for l in range(L):
        # (B, S, K, T) candidate pool -> K smallest per (B, T)
        cand = (dist[:, :, :, None] + Ws[:, l, :, None, :]).reshape(B, S * K, S)
        idx = np.argsort(cand, axis=1, kind="stable")[:, :K, :]    # (B, K, T)
        val = np.take_along_axis(cand, idx, axis=1)
        new = np.moveaxis(val, 1, 2)                               # (B, T, K)
        src = np.moveaxis(idx, 1, 2)
        fin = np.isfinite(new)
        ps.append(np.where(fin, src // K, -1))
        pk.append(np.where(fin, src % K, -1))
        hist.append(new)
        dist = new
    return (np.stack(hist, axis=1), np.stack(ps, axis=1).astype(np.int64),
            np.stack(pk, axis=1).astype(np.int64))
