"""Pure-jnp oracle for the ee_gate kernel."""
import jax
import jax.numpy as jnp


@jax.jit
def ee_gate_ref(logits: jnp.ndarray):
    """logits: [B, V] -> (conf [B] f32, argmax [B] i32)."""
    x = jnp.maximum(logits.astype(jnp.float32), -3.0e38)
    m = x.max(axis=-1)
    lse = m + jnp.log(jnp.exp(x - m[:, None]).sum(axis=-1))
    return jnp.exp(m - lse), jnp.argmax(x, axis=-1).astype(jnp.int32)
