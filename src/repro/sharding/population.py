"""Device-mesh execution layer for population-scale banded relaxations.

The population engine's per-tick DP work is a stack of independent banded
relaxation chains — one (L-1, N, G+1) chain per dirty cohort state (or per
user when no two users share a quantized state).  That is embarrassingly
data-parallel over the leading axis, so the mesh layer shards it the same
way serving-oriented systems shard heavy multi-user traffic: a 1-D jax
mesh over a ``"users"`` axis, the stacked (D, L-1, N, N) tensors laid out
``PartitionSpec("users")`` on dim 0, and the jitted relaxation program
running one shard per device with the distance grid carried on-device
across the layer scan (nothing round-trips through the host between
layers).

On this container the mesh is host-platform devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before importing
jax — see the README scaling quickstart); on TPU the same program lands on
real chips with the banded Pallas kernel as the per-shard engine
(``interpret=False`` in ``kernels/minplus``).  Like the ``jnp``/``pallas``
backends, the mesh engine relaxes in float32 — ``Population`` widens its
exit-prune guard accordingly (``tolerances.DIST_RTOL_F32``); the float64
numpy fallback (``backend="minplus"``) remains the bit-exact reference.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.bellman_ford import _banded_relax_scan_jnp

__all__ = ["population_mesh", "MeshRelaxer"]


def population_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D mesh over the ``"users"`` axis (default: every visible device).

    Start the process with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` to expose K host
    devices on CPU-only machines.
    """
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(f"requested {n_devices} devices but only "
                             f"{len(devs)} are visible (set XLA_FLAGS="
                             f"--xla_force_host_platform_device_count)")
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), axis_names=("users",))


@functools.partial(jax.jit, static_argnames=("lo",))
def _mesh_relax(init: jnp.ndarray, E: jnp.ndarray, st: jnp.ndarray,
                lo: Optional[int]):
    """Jitted chained relaxation: the distance grid is the scan carry, so
    it lives in device memory across the whole layer chain — the only
    host<->device transfers are the stacked inputs in and the
    history/parents out, once per tick."""
    return _banded_relax_scan_jnp(init, E, st, lo)


class MeshRelaxer:
    """Sharded chained banded relaxation over a ``"users"`` mesh axis.

    ``relax`` has the ``bellman_ford.batched_banded_relax_argmin``
    contract: init (D, N, G+1), E/steep (D, L, N, N) -> (hist
    (D, L+1, N, G+1) float64, par (D, L, N, G+1) int64).  D is padded to a
    device multiple with empty (all-inf) scenarios; each device relaxes
    its shard independently — there is no cross-shard communication in the
    banded DP, so scaling is linear until the per-device shard no longer
    hides dispatch overhead.
    """

    def __init__(self, mesh: Optional[Mesh] = None):
        self.mesh = mesh if mesh is not None else population_mesh()
        self._sharding = NamedSharding(self.mesh, P("users"))

    @property
    def n_devices(self) -> int:
        return int(self.mesh.devices.size)

    def relax(self, init: np.ndarray, E: np.ndarray, steep: np.ndarray,
              lo: Optional[int]) -> Tuple[np.ndarray, np.ndarray]:
        D, N, Gp1 = init.shape
        L = E.shape[1]
        if L == 0:
            return (np.asarray(init)[:, None].astype(np.float64),
                    np.zeros((D, 0, N, Gp1), dtype=np.int64))
        finite = np.isfinite(steep)
        sti = np.where(finite, steep, 0).astype(np.int32)
        Ef = np.where(finite, E, np.inf).astype(np.float32)
        initf = np.asarray(init, np.float32)
        n = self.n_devices
        pad = (-D) % n
        if pad:
            initf = np.concatenate(
                [initf, np.full((pad, N, Gp1), np.inf, np.float32)])
            Ef = np.concatenate(
                [Ef, np.full((pad, L, N, N), np.inf, np.float32)])
            sti = np.concatenate([sti, np.zeros((pad, L, N, N), np.int32)])
        dev = jax.device_put(jnp.asarray(initf), self._sharding)
        Ed = jax.device_put(jnp.asarray(Ef), self._sharding)
        sd = jax.device_put(jnp.asarray(sti), self._sharding)
        hist, par = _mesh_relax(dev, Ed, sd, lo)
        hist = np.asarray(hist, np.float64)[:D]
        par = np.asarray(par).astype(np.int64)[:D]
        # layer-0 history: the exact float64 init (parity with the jnp
        # engine, whose callers read hist[0] as the untouched init grid)
        hist[:, 0] = init
        return hist, par
