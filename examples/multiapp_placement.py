"""Multi-application orchestration demo (paper Sec. V / Fig. 8).

Six applications (three branchy DNNs x two datasets) share the multi-tier
system under resource slicing; FIN and MCP place every user's inference
pipeline.  Prints per-app energy gain, tier usage, failure rates and exit
distributions.

Run:  PYTHONPATH=src python examples/multiapp_placement.py [--users 30]
"""
import argparse
import sys

import numpy as np

from repro.core import run_multiapp


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=30)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    res = run_multiapp(args.users, seed=args.seed)
    print(f"{args.users} users per app, per-execution slice 0.5% "
          f"of edge/cloud\n")
    hdr = (f"{'app':5s} {'E_fin/E_mcp':>11s} {'fail_fin':>8s} "
           f"{'fail_mcp':>8s}  tiers(FIN)                exits(FIN)")
    print(hdr)
    for app in ("h1", "h2", "h3", "h4", "h5", "h6"):
        fin = res.stats[app]["fin"]
        mcp = res.stats[app]["mcp"]
        tiers = ",".join(f"{t}:{p:.2f}" for t, p in
                         sorted(fin.tier_probs().items()))
        exits = "/".join(f"{p:.2f}" for p in fin.exit_probs())
        print(f"{app:5s} {res.energy_gain(app):11.3f} "
              f"{fin.failure_prob:8.2f} {mcp.failure_prob:8.2f}  "
              f"{tiers:25s} {exits}")
    gains = [res.energy_gain(a) for a in res.stats]
    print(f"\nmean FIN/MCP energy ratio: {np.nanmean(gains):.3f} "
          f"(paper: 0.65-0.70 — 'over 65% savings' headline)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
