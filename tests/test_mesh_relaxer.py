"""MeshRelaxer shaping contract: pad-and-strip for ragged scenario counts,
clear ValueErrors for malformed stacks, and f32 agreement with the float64
reference on every branch.

Runs against however many devices are visible; the pad-branch tests need a
multi-device mesh and are exercised with 4 host devices via
``tests/test_stream_subprocess.py`` (the main pytest process keeps the
default single CPU device).
"""
import jax
import numpy as np
import pytest

from repro.core.bellman_ford import batched_banded_relax_minarg
from repro.sharding.population import MeshRelaxer, population_mesh


def _case(D, seed=0, L=3, N=5, Gp1=11):
    rng = np.random.default_rng(seed)
    steep = np.where(rng.random((D, L, N, N)) < 0.5,
                     rng.integers(0, Gp1 - 1, (D, L, N, N)).astype(float),
                     np.inf)
    E = rng.random((D, L, N, N))
    init = np.where(rng.random((D, N, Gp1)) < 0.3,
                    rng.random((D, N, Gp1)), np.inf)
    return init, E, steep


def _check(mr, D, seed=0):
    init, E, steep = _case(D, seed)
    h, p = mr.relax(init, E, steep, None)
    assert h.shape == (D, 4, 5, 11)
    assert p.shape == (D, 3, 5, 11)
    h64, _ = batched_banded_relax_minarg(
        init, np.where(np.isfinite(steep), E, np.inf), steep, None)
    fin = np.isfinite(h64)
    assert np.array_equal(np.isfinite(h), fin)
    np.testing.assert_allclose(h[fin], h64[fin], rtol=1e-6)
    assert np.array_equal(h[:, 0], init)      # exact f64 init row


def test_divisible_counts_no_padding():
    mr = MeshRelaxer(population_mesh())
    _check(mr, 2 * mr.n_devices, seed=1)


def test_ragged_counts_pad_and_strip():
    mr = MeshRelaxer(population_mesh())
    for D in (1, mr.n_devices + 1, 3 * mr.n_devices - 1):
        _check(mr, D, seed=D)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="pad branch needs a multi-device mesh")
def test_pad_branch_on_multi_device_mesh():
    mr = MeshRelaxer(population_mesh(4))
    assert mr.n_devices == 4
    for D in (1, 3, 5, 7):                    # all force padding
        assert D % mr.n_devices != 0
        _check(mr, D, seed=10 + D)
    _check(mr, 8, seed=99)                    # and the exact-fit branch


def test_malformed_stacks_raise():
    mr = MeshRelaxer(population_mesh())
    init, E, steep = _case(4)
    with pytest.raises(ValueError, match="init must be"):
        mr.relax(init[:, 0], E, steep, None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E[:, :, :4], steep, None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E, steep[:2], None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E[:2], steep[:2], None)


def test_zero_layer_chain_short_circuits():
    mr = MeshRelaxer(population_mesh())
    init, _, _ = _case(3)
    h, p = mr.relax(init, np.empty((3, 0, 5, 5)), np.empty((3, 0, 5, 5)),
                    None)
    assert np.array_equal(h[:, 0], init) and p.shape == (3, 0, 5, 11)


def test_population_mesh_device_trim_validation():
    with pytest.raises(ValueError, match="visible"):
        population_mesh(jax.device_count() + 1)


# ---------------------------------------------------------------------------
# host-dropout recovery: bounded retry, then the demotion ladder
# ---------------------------------------------------------------------------

def test_retry_within_budget_no_demotion():
    from repro.core.faults import FaultPlan
    mr = MeshRelaxer(population_mesh(), max_retries=2, backoff_s=0.0)
    clean = MeshRelaxer(population_mesh())
    init, E, steep = _case(3, seed=21)
    hc, pc = clean.relax(init, E, steep, None)
    mr.fault_hook = FaultPlan.stall_hook(2)   # fails 2 of 3 attempts
    h, p = mr.relax(init, E, steep, None)
    assert mr.retries == 2 and mr.demotions == 0
    assert np.array_equal(h, hc) and np.array_equal(p, pc)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="demotion needs a multi-device local mesh")
def test_retry_budget_spent_demotes_bit_exact():
    from repro.core.faults import FaultPlan
    mr = MeshRelaxer(population_mesh(), max_retries=0, backoff_s=0.0)
    n0 = mr.n_devices
    clean = MeshRelaxer(population_mesh())
    init, E, steep = _case(5, seed=22)
    hc, pc = clean.relax(init, E, steep, None)
    mr.fault_hook = FaultPlan.stall_hook(1)   # kill the only attempt
    h, p = mr.relax(init, E, steep, None)
    assert mr.demotions == 1 and mr.n_devices == 1 < n0
    assert np.array_equal(h, hc) and np.array_equal(p, pc)
    # the relaxer stays usable on the demoted rung
    init2, E2, steep2 = _case(2, seed=23)
    h2, _ = mr.relax(init2, E2, steep2, None)
    h2c, _ = clean.relax(init2, E2, steep2, None)
    assert np.array_equal(h2, h2c)


def test_bottom_of_ladder_reraises():
    from repro.core.faults import FaultPlan
    mr = MeshRelaxer(population_mesh(), max_retries=0, backoff_s=0.0)
    n0 = mr.n_devices
    init, E, steep = _case(2, seed=24)
    mr.fault_hook = FaultPlan.stall_hook(10 ** 6)   # never heals
    with pytest.raises(TimeoutError, match="injected host stall"):
        mr.relax(init, E, steep, None)
    # ladder fully taken before giving up
    assert mr.n_devices == 1
    assert mr.demotions == (1 if n0 > 1 else 0)


def test_nonrecoverable_errors_are_not_retried():
    mr = MeshRelaxer(population_mesh(), max_retries=3, backoff_s=0.0)
    init, E, steep = _case(2, seed=25)

    def bomb(attempt):
        raise KeyError("not in RECOVERABLE")

    mr.fault_hook = bomb
    with pytest.raises(KeyError):
        mr.relax(init, E, steep, None)
    assert mr.retries == 0 and mr.demotions == 0
