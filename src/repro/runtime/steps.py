"""Train / serve step builders + ``input_specs`` (the dry-run contract).

``build_train_step(cfg)``  -> step(state, batch) -> (state, metrics)
``build_serve_step(cfg)``  -> step(params, caches, tokens, pos) -> (logits,
                              caches, exit_logits)
``build_encode_step(cfg)`` -> step(params, batch) -> logits   (encoder-only)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the corresponding step — weak-type-correct, shardable, and never
allocating (the multi-pod dry-run lowers against these).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec
from repro.models import transformer as T
from repro.models.layers import dtype_of
from repro.optim import AdamW, AdamWState, clip_by_global_norm


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_optimizer(cfg: ArchConfig) -> AdamW:
    return AdamW(lr=3e-4,
                 state_dtype=None if cfg.master_weights else "bfloat16")


def build_train_step(cfg: ArchConfig, *, clip_norm: float = 1.0):
    opt = make_optimizer(cfg)

    def train_step(state: dict, batch: dict) -> Tuple[dict, dict]:
        params, opt_state = state["params"], state["opt"]
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch))(params)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": new_opt.step}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(key, cfg: ArchConfig) -> dict:
    params = T.init_model(key, cfg)
    opt = make_optimizer(cfg)
    return {"params": params, "opt": opt.init(params)}


def train_state_shapes(cfg: ArchConfig):
    """ShapeDtypeStruct pytree of the train state — no allocation."""
    return jax.eval_shape(
        functools.partial(init_train_state, jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ArchConfig):
    def serve_step(params, caches, tokens, pos):
        return T.decode_step(params, cfg, tokens, caches, pos)
    return serve_step


def build_encode_step(cfg: ArchConfig):
    def encode_step(params, batch):
        return T.encode(params, cfg, batch)
    return encode_step


def build_prefill_step(cfg: ArchConfig, cache_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, cfg, batch, cache_len=cache_len)
    return prefill_step


def params_shapes(cfg: ArchConfig):
    return jax.eval_shape(functools.partial(T.init_model,
                                            jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins per (arch x shape) cell
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, jax.ShapeDtypeStruct]:
    dt = dtype_of(cfg.dtype)
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend == "vision":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), dt)
    specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """All step inputs as ShapeDtypeStructs, keyed by step argument."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"state": train_state_shapes(cfg),
                "batch": batch_specs(cfg, B, S)}
    if shape.kind == "prefill":
        b = batch_specs(cfg, B, S)
        b.pop("labels")
        return {"params": params_shapes(cfg), "batch": b}
    if shape.kind == "decode":
        assert cfg.has_decoder
        return {
            "params": params_shapes(cfg),
            "caches": T.cache_shape_dtypes(cfg, B, S),
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)


def step_for(cfg: ArchConfig, shape: ShapeSpec):
    """(callable, ordered argnames) for the cell's step function."""
    if shape.kind == "train":
        return build_train_step(cfg), ("state", "batch")
    if shape.kind == "prefill":
        if not cfg.has_decoder:
            return build_encode_step(cfg), ("params", "batch")

        def prefill_logits(params, batch):
            # lower prefill as pure forward (the cache write-back variant is
            # exercised by the runtime engine; shapes identical)
            return T.forward_train(params, cfg, batch)["final"][:, -1]
        return prefill_logits, ("params", "batch")
    if shape.kind == "decode":
        return build_serve_step(cfg), ("params", "caches", "tokens", "pos")
    raise ValueError(shape.kind)
