"""FIN solver tests: optimality vs Opt, feasibility, paper-scenario behaviour."""
import numpy as np
import pytest

from repro.core import (AppRequirements, Config, Network, build_extended_graph,
                        build_feasible_graph, evaluate_config, make_network,
                        paper_profile, solve_fin, solve_mcp, solve_opt,
                        synthetic_profile)
from repro.core.scenarios import paper_scenario


@pytest.fixture(scope="module")
def scenario():
    return paper_scenario()


@pytest.mark.parametrize("app", ["h1", "h2", "h3", "h4", "h5", "h6"])
def test_fin_matches_opt_on_paper_apps(scenario, app):
    """Sec. V: 'FIN virtually always matches the optimum' (gamma=10)."""
    prof = paper_profile(app)
    alpha = min(e.accuracy for e in prof.exits)  # always satisfiable
    req = AppRequirements(alpha=alpha, delta=8e-3, sigma=1.0)
    fin = solve_fin(scenario, prof, req, gamma=10)
    opt = solve_opt(scenario, prof, req)
    assert opt.feasible
    assert fin.feasible
    assert fin.energy <= opt.energy * (1 + 1.0 / 10) + 1e-15


def test_fin_solution_is_feasible_by_construction(scenario):
    prof = paper_profile("h2")
    for delta in (2e-3, 5e-3, 12e-3):
        for alpha in (0.5, 0.8):
            sol = solve_fin(scenario, prof, AppRequirements(alpha, delta), gamma=10)
            if sol.found:
                assert sol.feasible, sol.eval.violations


def test_fin_infeasible_alpha_returns_none(scenario):
    prof = paper_profile("h2")  # best exit accuracy 0.8595
    sol = solve_fin(scenario, prof, AppRequirements(alpha=0.95, delta=1.0))
    assert not sol.found
    assert "3c" in sol.meta["reason"] or "alpha" in sol.meta["reason"]


def test_fin_tight_latency_forces_split_or_fast_tier(scenario):
    """Fig. 5: small delta forces offloading; large delta keeps mobile-only."""
    prof = paper_profile("h2")
    tight = solve_fin(scenario, prof, AppRequirements(0.80, 2e-3), gamma=10)
    loose = solve_fin(scenario, prof, AppRequirements(0.80, 12e-3), gamma=10)
    assert tight.feasible and loose.feasible
    assert loose.config.placement == [0] * 5       # all on mobile
    assert any(p != 0 for p in tight.config.placement)
    assert loose.energy <= tight.energy            # energy-latency trade-off


def test_fin_energy_monotone_in_delta(scenario):
    """Looser latency targets can only reduce (or keep) the optimal energy."""
    prof = paper_profile("h1")
    req_alpha = 0.54
    prev = np.inf
    for delta in (1.5e-3, 3e-3, 6e-3, 12e-3, 24e-3):
        sol = solve_fin(scenario, prof, AppRequirements(req_alpha, delta), gamma=16)
        if sol.feasible:
            assert sol.energy <= prev * (1 + 1.0 / 16) + 1e-15
            prev = min(prev, sol.energy)


def test_gamma_refines_solution_quality(scenario):
    """Property 2: competitive ratio 1 + 1/gamma for adequate resolution.

    The bound holds for gamma >= 10 (the paper's working point).  At gamma=3
    depth-state collisions of the scaled quantizer can lose the optimal path
    — the paper itself observes gamma=3 'deteriorates significantly' on the
    communication term (Fig. 6); we only require feasibility there.
    """
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 4e-3)
    opt = solve_opt(scenario, prof, req)
    assert opt.feasible
    energies = {}
    for gamma in (3, 10, 40):
        sol = solve_fin(scenario, prof, req, gamma=gamma)
        assert sol.feasible
        energies[gamma] = sol.energy
        if gamma >= 10:
            assert sol.energy <= opt.energy * (1 + 1.0 / gamma) + 1e-15
    assert energies[40] <= energies[10] + 1e-15  # refinement is monotone here


def test_lambda_proximity_restriction(scenario):
    """lam=gamma is exhaustive; small lam is a heuristic that may only prune."""
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 5e-3)
    full = solve_fin(scenario, prof, req, gamma=10, lam=10)
    assert full.feasible
    pruned = solve_fin(scenario, prof, req, gamma=10, lam=3)
    if pruned.feasible:
        assert pruned.energy >= full.energy - 1e-15


def test_feasible_graph_counts(scenario):
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 5e-3)
    ext = build_extended_graph(scenario, prof, req)
    fg = build_feasible_graph(ext, gamma=10)
    assert fg.n_states == scenario.n_nodes * 11
    assert fg.n_vertices == prof.n_blocks * fg.n_states + 1
    assert fg.n_edges > 0
    # gamma replication: more resolution => at least as many edges
    fg2 = build_feasible_graph(ext, gamma=20)
    assert fg2.n_edges >= fg.n_edges


def test_quantize_ceil_guarantees_latency(scenario):
    """ceil quantization: any returned path meets (3b) without tightening."""
    prof = paper_profile("h5")  # 3 blocks — fits in gamma=8 even with ceil
    req = AppRequirements(0.90, 1e-3)
    sol = solve_fin(scenario, prof, req, gamma=8, quantize="ceil", max_tighten=0)
    if sol.found:
        assert sol.eval.latency <= req.delta + 1e-12


def test_fault_tolerance_replacement(scenario):
    """Node failure: re-solve on the reduced network (DESIGN.md Sec. 5)."""
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 2e-3)
    sol = solve_fin(scenario, prof, req, gamma=10)
    assert sol.feasible
    used = {p for p in sol.config.placement if p != 0}
    if used:
        failed = used.pop()
        reduced = scenario.without_node(failed)
        sol2 = solve_fin(reduced, prof, req, gamma=10)
        if sol2.found:
            assert sol2.feasible
            assert sol2.energy >= sol.energy - 1e-15  # fewer options can't win


def test_evaluate_config_violations(scenario):
    prof = paper_profile("h2")
    req = AppRequirements(alpha=0.99, delta=1e-6)
    cfg = Config(placement=[0] * 5, final_exit=2)
    ev = evaluate_config(scenario, prof, req, cfg)
    assert not ev.feasible
    kinds = " ".join(ev.violations)
    assert "(3b)" in kinds and "(3c)" in kinds


def test_energy_decomposition_consistency(scenario):
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 5e-3)
    sol = solve_fin(scenario, prof, req, gamma=10)
    ev = sol.eval
    assert ev.energy == pytest.approx(ev.energy_comp + ev.energy_comm)
    assert ev.energy_comp > 0


def test_steiner_like_instance():
    """Property 1 flavor: a hub-constrained instance — only one node can run
    the block; the solver must route through it or fail."""
    nw = make_network(("mobile", "edge", "cloud"),
                      compute_frac=(1e-9, 1.0, 1e-9))
    prof = synthetic_profile(1, 1, seed=3)
    req = AppRequirements(alpha=0.0, delta=10.0, sigma=1e-9)
    sol = solve_fin(nw, prof, req, gamma=10)
    assert sol.feasible
    assert sol.config.placement == [1]


def test_k_best_dp_fixes_small_gamma_collisions(scenario):
    """Beyond-paper: keeping the k cheapest paths per (node, depth) state
    restores optimality at gamma=3, where the 1-best DP provably loses the
    optimal path to a quantizer state collision (EXPERIMENTS §Perf)."""
    prof = paper_profile("h2")
    req = AppRequirements(0.80, 4e-3)
    opt = solve_opt(scenario, prof, req)
    one = solve_fin(scenario, prof, req, gamma=3, n_best=1)
    four = solve_fin(scenario, prof, req, gamma=3, n_best=4)
    assert opt.feasible and one.feasible and four.feasible
    assert four.energy <= one.energy + 1e-15
    assert four.energy <= opt.energy * (1 + 1.0 / 3) + 1e-15
    # on this instance k-best recovers the exact optimum
    assert four.energy == pytest.approx(opt.energy)
