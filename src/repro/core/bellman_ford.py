"""(min,+) relaxation primitives backing FIN's minimum-cost traversal.

FIN's feasible graph is a layered DAG over states s = (node, depth); the
minimum-cost traversal is a sequence of (min,+) ("tropical") matrix-vector
products — exactly a Bellman-Ford relaxation restricted to the layer
structure.  Two families of engines:

  * dense    — (S, S) flattened-state matrices, S = N*(gamma+1)
               (numpy reference with argmin backtracking, jitted jnp, and
               the dense ``minplus`` TPU kernel); O(N^2 G^2) per layer,
               kept for equivalence testing and the k-best mode;
  * banded   — the compact (N, G+1) grid exploiting the graph's band
               structure in depth (see the banded section below): numpy
               (float64, bit-exact vs dense), jnp (f32 lax.scan), and the
               banded ``minplus`` Pallas kernel; O(N^2 G) per layer.

The paper reports solver wall-time (Table VII), so this *is* a hot spot the
paper measures; on TPU the relaxation maps naturally onto the VPU with
(min,+) in place of (+,*) — see kernels/minplus/minplus.py.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# cache-residency chunking (shared by every batched relaxation call site)
# ---------------------------------------------------------------------------

#: default per-chunk budget for a batched relaxation's candidate tensor
#: ((D, N, N, G+1) banded / (D, S, S) dense); override with the
#: REPRO_RELAX_CHUNK_BYTES environment variable (see docs/ARCHITECTURE.md).
_RELAX_CHUNK_BYTES_DEFAULT = 4 << 20


def relax_chunk_bytes() -> int:
    """Cache-residency budget (bytes) for one relaxation chunk's candidate
    tensor.  Beyond ~L2/L3 size the broadcast turns memory-bound and batched
    throughput collapses; the chunk count is derived from this budget and
    the per-scenario candidate size (compact banded or dense).

    A set-but-invalid REPRO_RELAX_CHUNK_BYTES raises immediately (an unset
    or empty variable means the default): a typo'd budget silently falling
    back would only surface as an inexplicable perf cliff deep inside the
    chunked relaxation.
    """
    raw = os.environ.get("REPRO_RELAX_CHUNK_BYTES", "")
    if not raw:
        return _RELAX_CHUNK_BYTES_DEFAULT
    try:
        val = int(raw)
    except ValueError:
        raise ValueError(
            f"REPRO_RELAX_CHUNK_BYTES must be a positive integer (bytes), "
            f"got {raw!r}") from None
    if val <= 0:
        raise ValueError(
            f"REPRO_RELAX_CHUNK_BYTES must be a positive integer (bytes), "
            f"got {raw!r}")
    return val


def relax_chunk_rows(bytes_per_row: int) -> int:
    """Scenario rows per cache-resident relaxation chunk.

    ``bytes_per_row`` is the size of ONE scenario's live working set inside
    the batched relaxation (candidate tensor plus whatever per-scenario
    index/argmin payload rides along).  Always at least 1, so callers never
    have to special-case a single over-budget scenario.  This is the one
    home of the ``max(1, budget // row_bytes)`` arithmetic that the solver
    (``fin._run_dp_batch``), the plan IR (``plan._warm_round0``) and the
    population engine all share.
    """
    if bytes_per_row <= 0:
        raise ValueError(f"bytes_per_row must be positive, got "
                         f"{bytes_per_row!r}")
    return max(1, relax_chunk_bytes() // bytes_per_row)


# ---------------------------------------------------------------------------
# numpy reference
# ---------------------------------------------------------------------------

def minplus_vecmat_np(dist: np.ndarray, W: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """out[t] = min_s dist[s] + W[s, t]; returns (out, argmin_s)."""
    cand = dist[:, None] + W                     # (S, T)
    arg = np.argmin(cand, axis=0)
    out = cand[arg, np.arange(W.shape[1])]
    return out, arg


def bellman_ford_np(W: np.ndarray, src: int, *, max_iters: Optional[int] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Classic dense Bellman-Ford on an (S, S) weight matrix (inf = no edge).

    Returns (dist, parent).  Used to cross-validate the layered DP and to
    solve non-layered instances (e.g. MCP on general meshes).
    """
    S = W.shape[0]
    dist = np.full(S, np.inf)
    parent = np.full(S, -1, dtype=np.int64)
    dist[src] = 0.0
    iters = max_iters if max_iters is not None else S - 1
    for _ in range(iters):
        new, arg = minplus_vecmat_np(dist, W)
        improved = new < dist - 1e-18
        if not improved.any():
            break
        parent[improved] = arg[improved]
        dist = np.where(improved, new, dist)
    return dist, parent


# ---------------------------------------------------------------------------
# jnp (jit) backend
# ---------------------------------------------------------------------------

@jax.jit
def minplus_vecmat_jnp(dist: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """out[t] = min_s dist[s] + W[s, t] (cost only, differentiable-free)."""
    return jnp.min(dist[:, None] + W, axis=0)


@jax.jit
def minplus_matmat_jnp(A: jnp.ndarray, B: jnp.ndarray) -> jnp.ndarray:
    """Tropical matmul: out[i, j] = min_k A[i, k] + B[k, j].

    This is the batched form used when relaxing many sources at once
    (multi-application orchestration relaxes one row per user).
    """
    return jnp.min(A[:, :, None] + B[None, :, :], axis=1)


def layered_relax_jnp(init: jnp.ndarray, Ws: jnp.ndarray) -> jnp.ndarray:
    """Relax through a stack of layer transition matrices via lax.scan.

    init: (S,) initial distances; Ws: (L, S, S).  Returns (L+1, S) distances
    after each layer.  jit-compiled once per (S, L) shape.
    """
    def step(dist, W):
        new = minplus_vecmat_jnp(dist, W)
        return new, new

    _, hist = jax.lax.scan(step, init, Ws)
    return jnp.concatenate([init[None], hist], axis=0)


def layered_relax(init: np.ndarray, Ws: np.ndarray, backend: str = "numpy",
                  ) -> np.ndarray:
    """Dispatch layered relaxation to a backend. Returns (L+1, S) distances."""
    if backend == "numpy":
        out = [init]
        d = init
        for W in Ws:
            d, _ = minplus_vecmat_np(d, W)
            out.append(d)
        return np.stack(out)
    if backend == "jnp":
        return np.asarray(layered_relax_jnp(jnp.asarray(init), jnp.asarray(Ws)))
    if backend == "pallas":
        from repro.kernels.minplus.ops import minplus_vecmat as mp_pallas
        out = [init]
        d = jnp.asarray(init, jnp.float32)
        for W in Ws:
            d = mp_pallas(d[None, :], jnp.asarray(W, jnp.float32))[0]
            out.append(np.asarray(d))
        return np.stack(out)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# argmin-tracking relaxation (exact path reconstruction for the FIN DP)
# ---------------------------------------------------------------------------

def layered_relax_argmin(init: np.ndarray, Ws: np.ndarray,
                         backend: str = "numpy"
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Layered relaxation with parent recovery.

    init: (S,), Ws: (L, S, S).  Returns (dist, parent) where dist is (L+1, S)
    distances after each layer and parent is (L, S): parent[l, t] is the
    argmin source state in layer l for state t in layer l+1, or -1 where t is
    unreached.  Single-scenario view of ``batched_layered_relax_argmin``
    (which see for the backend contract); the pallas backend runs the
    ``minplus`` argmin kernel layer by layer.
    """
    hist, par = batched_layered_relax_argmin(np.asarray(init)[None],
                                             np.asarray(Ws)[None],
                                             backend=backend)
    return hist[0], par[0]


def batched_layered_relax_argmin(init: np.ndarray, Ws: np.ndarray,
                                 backend: str = "numpy"
                                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched layered relaxation with parents: one (min,+) chain per scenario.

    init: (B, S); Ws: (B, L, S, S).  Returns (dist (B, L+1, S), parent
    (B, L, S)); parent is -1 where the target state is unreachable.  Backends:
    ``numpy`` (vectorized over the whole batch per layer), ``jnp`` (one
    lax.scan over layers, batch in the leading dim), ``pallas`` (argmin
    kernel, looped per scenario — per-scenario W defeats the shared-W kernel
    batching; block-diagonal matmat batching is the TPU follow-up).
    """
    B, S = init.shape
    L = Ws.shape[1]
    if L == 0:                       # single-block chain: no transitions
        return (np.asarray(init)[:, None, :].astype(np.float64),
                np.zeros((B, 0, S), dtype=np.int64))
    if backend == "numpy":
        dist = init
        hist = [dist]
        pars = []
        cand = np.empty((B, S, S), dtype=np.float64)   # reused across layers
        for l in range(L):
            np.add(dist[:, :, None], Ws[:, l], out=cand)     # (B, S, T)
            arg = np.argmin(cand, axis=1)
            new = np.take_along_axis(cand, arg[:, None, :], axis=1)[:, 0, :]
            pars.append(np.where(np.isfinite(new), arg, -1))
            hist.append(new)
            dist = new
        return np.stack(hist, axis=1), np.stack(pars, axis=1).astype(np.int64)
    if backend == "jnp":
        def step(d, W):
            cand = d[:, :, None] + W                         # (B, S, T)
            new = jnp.min(cand, axis=1)
            arg = jnp.argmin(cand, axis=1)
            return new, (new, jnp.where(jnp.isfinite(new), arg, -1))
        _, (h, p) = jax.lax.scan(step, jnp.asarray(init),
                                 jnp.moveaxis(jnp.asarray(Ws), 1, 0))
        hist = np.concatenate([np.asarray(init)[:, None],
                               np.moveaxis(np.asarray(h), 0, 1)], axis=1)
        return hist, np.moveaxis(np.asarray(p), 0, 1).astype(np.int64)
    if backend == "pallas":
        from repro.kernels.minplus.ops import minplus_vecmat_argmin
        hists, pars = [], []
        for b in range(B):
            d = jnp.asarray(init[b], jnp.float32)
            hist = [np.asarray(init[b])]
            par = []
            for W in Ws[b]:
                out, arg = minplus_vecmat_argmin(
                    d[None, :], jnp.asarray(W, jnp.float32))
                d = out[0]
                hist.append(np.asarray(d, np.float64))
                par.append(np.asarray(arg[0], np.int64))
            hists.append(np.stack(hist))
            pars.append(np.stack(par))
        return np.stack(hists), np.stack(pars)
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# k-best relaxation (beyond-paper quantizer-collision fix, vectorized)
# ---------------------------------------------------------------------------

def batched_layered_relax_min(init: np.ndarray, Ws: np.ndarray) -> np.ndarray:
    """Batched layered relaxation, distances only (numpy).

    init: (B, S); Ws: (B, L, S, S).  Returns dist (B, L+1, S).  The parent
    tensor is deliberately NOT computed: callers that need path
    reconstruction recover a parent with one argmin column scan per
    backtracked step (see fin._FlatDP) — orders of magnitude fewer argmins
    than materializing (B, L, S) parents when only a handful of end states
    are ever traced back.
    """
    B, S = init.shape
    L = Ws.shape[1]
    if L == 0:
        return np.asarray(init)[:, None, :].astype(np.float64)
    dist = init
    hist = [dist]
    cand = np.empty((B, S, S), dtype=np.float64)   # reused across layers
    for l in range(L):
        np.add(dist[:, :, None], Ws[:, l], out=cand)
        dist = np.min(cand, axis=1)
        hist.append(dist)
    return np.stack(hist, axis=1)


def batched_layered_relax_kbest(init: np.ndarray, Ws: np.ndarray, K: int
                                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Keep the K cheapest paths per state while relaxing layer by layer.

    init: (B, S); Ws: (B, L, S, S).  Returns (dist (B, L+1, S, K), par_s,
    par_k (B, L, S, K)) — the k-th cheapest distance at each state with the
    (source state, source rank) that produced it (-1 where unused).  Each
    layer sorts the S*K candidate pool per target with a stable argsort, so
    tie order is deterministic (source-state-major).  numpy only: K > 1 is
    the beyond-paper small-gamma mode and stays far from the hot path.
    """
    B, S = init.shape
    L = Ws.shape[1]
    dist = np.full((B, S, K), np.inf)
    dist[:, :, 0] = init
    if L == 0:
        return (dist[:, None], np.zeros((B, 0, S, K), dtype=np.int64),
                np.zeros((B, 0, S, K), dtype=np.int64))
    hist = [dist]
    ps, pk = [], []
    for l in range(L):
        # (B, S, K, T) candidate pool -> K smallest per (B, T)
        cand = (dist[:, :, :, None] + Ws[:, l, :, None, :]).reshape(B, S * K, S)
        idx = np.argsort(cand, axis=1, kind="stable")[:, :K, :]    # (B, K, T)
        val = np.take_along_axis(cand, idx, axis=1)
        new = np.moveaxis(val, 1, 2)                               # (B, T, K)
        src = np.moveaxis(idx, 1, 2)
        fin = np.isfinite(new)
        ps.append(np.where(fin, src // K, -1))
        pk.append(np.where(fin, src % K, -1))
        hist.append(new)
        dist = new
    return (np.stack(hist, axis=1), np.stack(ps, axis=1).astype(np.int64),
            np.stack(pk, axis=1).astype(np.int64))


# ---------------------------------------------------------------------------
# depth-banded relaxation (compact (node, depth) states, no (S, S) tensors)
# ---------------------------------------------------------------------------
#
# The feasible graph's transition structure is *banded* in depth: an edge only
# connects (n, g) to (n', g + steep[n, n']), so the dense (S, S) layer matrix
# with S = N*(G+1) holds exactly one finite entry per (source node, target
# state) pair.  The relaxation over the compact (N, G+1) distance grid is a
# shift-by-steep gather + min over source nodes:
#
#   new[n', g'] = min_n  dist[n, g' - steep[n, n']] + E[n, n']
#
# (inadmissible where g' - steep < 0, the edge is pruned, or the
# lambda-proximity window excludes g').  Per-layer work and memory drop from
# O(N^2 G^2) to O(N^2 G) — a (gamma+1)-fold win over the dense path.
#
# Equivalence with the dense engines is exact on the numpy path: the banded
# candidate set per target state is identical to the finite entries of the
# dense column, the float64 adds are the same operations, and the argmin-
# over-source-nodes tie order equals the dense first-occurrence flat-state
# order (states are node-major, and each source node contributes at most one
# candidate depth per target).

def _banded_gather_idx(steep: np.ndarray, Gp1: int,
                       lo: Optional[int]) -> np.ndarray:
    """(..., N, N, G+1) int32 source-depth gather indices for banded layers.

    steep: (..., N, N) integer steepness (inf = pruned).  Index g - steep per
    target depth g; every inadmissible candidate (pruned edge, negative
    source depth, lambda window) is routed to the sentinel index ``Gp1`` —
    gathering from a distance grid padded with one inf column then yields
    the fully masked candidate tensor with no boolean where-pass over it.
    """
    finite = np.isfinite(steep)
    # sentinel Gp1 steepness makes every source depth negative -> inf column
    sti = np.where(finite, steep, Gp1).astype(np.int32)
    g = np.arange(Gp1, dtype=np.int32)
    idx = g - sti[..., None]
    if lo is not None:
        np.copyto(idx, np.int32(-1), where=(g < lo) & (sti[..., None] != 0))
    np.copyto(idx, np.int32(Gp1), where=idx < 0)
    return idx


def batched_banded_relax_min(init: np.ndarray, E: np.ndarray,
                             steep: np.ndarray,
                             lo: Optional[int] = None,
                             *, idx: Optional[np.ndarray] = None) -> np.ndarray:
    """Banded layered relaxation, distances only (numpy, float64 exact).

    init: (B, N, G+1); E/steep: (B, L, N, N).  Returns hist
    (B, L+1, N, G+1).  Distances are bit-for-bit equal to the dense
    ``batched_layered_relax_min`` on the scattered (S, S) matrices — the
    banded candidate set per target state is exactly the finite entries of
    the dense column, computed with the same float64 adds.

    ``idx`` optionally supplies the (B, L, N, N, G+1) gather-index tensor
    (``_banded_gather_idx(steep, G+1, lo)``) precomputed by the caller — the
    incremental ``Plan`` layer maintains it across deltas (only mutated
    rows/cols are recomputed), turning the per-solve index build into a
    no-op on the warm path.  When given, ``steep`` is not read.
    """
    B, N, Gp1 = init.shape
    L = E.shape[1]
    dist = np.asarray(init, dtype=np.float64)
    if L == 0:
        return dist[:, None]
    if idx is None:
        # all layers' gather indices in one vectorized pass (O(L N^2 G))
        idx = _banded_gather_idx(steep, Gp1, lo)         # (B, L, N, N, G+1)
    pad = np.empty((B, N, Gp1 + 1))                      # dist + inf column
    pad[:, :, Gp1] = np.inf
    b_i = np.arange(B)[:, None, None, None]
    n_i = np.arange(N)[None, :, None, None]
    hist = [dist]
    for l in range(L):
        pad[:, :, :Gp1] = dist
        cand = pad[b_i, n_i, idx[:, l]]                  # (B, N, N, G+1)
        cand += E[:, l, :, :, None]
        dist = cand.min(axis=1)                          # (B, N, G+1)
        hist.append(dist)
    return np.stack(hist, axis=1)


def batched_banded_relax_minarg(init: np.ndarray, E: np.ndarray,
                                steep: np.ndarray,
                                lo: Optional[int] = None,
                                *, idx: Optional[np.ndarray] = None
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """Banded relaxation with stored argmin parents (numpy, float64 exact).

    Same contract as :func:`batched_banded_relax_min` (distances are
    bit-identical — the min is read back through the argmin), plus par_n
    (B, L, N, G+1) int64: the argmin *source node* of each state, -1 where
    unreachable, with the same first-occurrence tie order as
    ``banded_parent_np`` / the dense flat-state column argmin.  This is the
    engine of the incremental ``Plan`` layer: a warm plan backtracks its DP
    grid repeatedly across churn ticks, so paying one vectorized argmin per
    relaxation beats re-deriving parents with per-step candidate scans.
    ``idx`` as in ``batched_banded_relax_min``.
    """
    B, N, Gp1 = init.shape
    L = E.shape[1]
    dist = np.asarray(init, dtype=np.float64)
    if L == 0:
        return dist[:, None], np.zeros((B, 0, N, Gp1), dtype=np.int64)
    if idx is None:
        idx = _banded_gather_idx(steep, Gp1, lo)
    pad = np.empty((B, N, Gp1 + 1))
    pad[:, :, Gp1] = np.inf
    b_i = np.arange(B)[:, None, None, None]
    n_i = np.arange(N)[None, :, None, None]
    hist = [dist]
    pars = []
    for l in range(L):
        pad[:, :, :Gp1] = dist
        cand = pad[b_i, n_i, idx[:, l]]                  # (B, N, N, G+1)
        cand += E[:, l, :, :, None]
        arg = np.argmin(cand, axis=1)                    # (B, N, G+1)
        # min == cand[argmin] exactly (no NaNs in the tropical semiring),
        # and one fused reduction beats a take_along_axis gather
        dist = cand.min(axis=1)
        pars.append(np.where(np.isfinite(dist), arg, -1))
        hist.append(dist)
    return np.stack(hist, axis=1), np.stack(pars, axis=1)


def batched_banded_relax_kbest(init: np.ndarray, E: np.ndarray,
                               steep: np.ndarray, K: int,
                               lo: Optional[int] = None,
                               *, idx: Optional[np.ndarray] = None
                               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Banded k-slot relaxation: the K cheapest paths per (node, depth).

    init: (B, N, G+1); E/steep: (B, L, N, N).  Returns (hist
    (B, L+1, N, G+1, K), par_n, par_k (B, L, N, G+1, K) int64, -1 where the
    slot is unused); the parent *depth* is implied by the band: g_src =
    g - steep[par_n, n].  Distances and slot order are bit-for-bit equal to
    the dense ``batched_layered_relax_kbest`` on the scattered (S, S)
    matrices: per target state each source node contributes at most one
    candidate depth, so the banded (source-node-major, rank-minor) pool
    order equals the dense flat-state (source-state-major, rank-minor)
    order, and both engines pick the K smallest with a stable argsort over
    the same float64 sums.  This is the k-best engine behind the Pareto
    frontier subsystem (``core/frontier.py``): where the K=1 engines keep
    only the energy argmin per state, the k slots carry the alternative
    placements the frontier is built from.

    ``idx`` as in :func:`batched_banded_relax_min` — the incremental
    ``Plan`` layer passes its maintained gather indices so warm k-best
    re-solves skip the index build.
    """
    if K < 1:
        raise ValueError(f"K must be >= 1, got {K}")
    B, N, Gp1 = init.shape
    L = E.shape[1]
    dist = np.full((B, N, Gp1, K), np.inf)
    dist[..., 0] = np.asarray(init, dtype=np.float64)
    if L == 0:
        return (dist[:, None], np.zeros((B, 0, N, Gp1, K), dtype=np.int64),
                np.zeros((B, 0, N, Gp1, K), dtype=np.int64))
    if idx is None:
        idx = _banded_gather_idx(steep, Gp1, lo)         # (B, L, N, N, G+1)
    pad = np.empty((B, N, Gp1 + 1, K))                   # dist + inf column
    pad[:, :, Gp1] = np.inf
    b_i = np.arange(B)[:, None, None, None]
    n_i = np.arange(N)[None, :, None, None]
    hist = [dist]
    pns, pks = [], []
    for l in range(L):
        pad[:, :, :Gp1] = dist
        cand = pad[b_i, n_i, idx[:, l]]                  # (B, N, N, G+1, K)
        cand += E[:, l, :, :, None, None]
        # candidate pool per target state: source-node-major, rank-minor —
        # the same relative order as the dense flat-state pool (states are
        # node-major and each source node contributes one depth per target)
        pool = np.ascontiguousarray(np.moveaxis(cand, 4, 2))
        pool = pool.reshape(B, N * K, N, Gp1)
        sel = np.argsort(pool, axis=1, kind="stable")[:, :K]   # (B, K, N, G+1)
        val = np.take_along_axis(pool, sel, axis=1)
        dist = np.moveaxis(val, 1, 3)                    # (B, N, G+1, K)
        src = np.moveaxis(sel, 1, 3)
        ok = np.isfinite(dist)
        pns.append(np.where(ok, src // K, -1))
        pks.append(np.where(ok, src % K, -1))
        hist.append(dist)
    return (np.stack(hist, axis=1), np.stack(pns, axis=1).astype(np.int64),
            np.stack(pks, axis=1).astype(np.int64))


def batched_banded_relax_kbest_pallas(init: np.ndarray, E: np.ndarray,
                                      steep: np.ndarray, K: int,
                                      lo: Optional[int] = None
                                      ) -> Tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
    """k-best variant of the chained banded pallas engine (float32).

    Same contract as :func:`batched_banded_relax_kbest`; the whole
    (B, L) batch relaxes as one chained kernel launch per scenario with
    the (N, K, G+1) k-slot distance grid carried in VMEM across layers
    (see ``kernels/minplus``).  Slot order matches the numpy engine's
    stable-argsort order (iterated first-occurrence argmin extraction);
    distances carry the usual f32 relaxation error.
    """
    from repro.kernels.minplus.ops import banded_minplus_chain_kbest
    B, N, Gp1 = init.shape
    finite = np.isfinite(steep)
    sti = np.where(finite, steep, 0).astype(np.int32)
    Ef = np.where(finite, E, np.inf).astype(np.float32)
    import jax.numpy as _jnp
    h, pn, pk = banded_minplus_chain_kbest(
        _jnp.asarray(np.asarray(init, np.float32)), _jnp.asarray(Ef),
        _jnp.asarray(sti), K, lo=lo)
    init64 = np.full((B, 1, N, Gp1, K), np.inf)
    init64[:, 0, :, :, 0] = init
    hist = np.concatenate([init64, np.asarray(h, np.float64)], axis=1)
    return (hist, np.asarray(pn).astype(np.int64),
            np.asarray(pk).astype(np.int64))


def banded_parent_np(dist_prev: np.ndarray, E_l: np.ndarray, st_l: np.ndarray,
                     n: int, g: int, lo: Optional[int]) -> Tuple[int, int]:
    """Recover the argmin parent of target state (n, g) for one layer.

    dist_prev: (N, G+1) previous-layer distances; E_l/st_l: (N, N).  Returns
    (parent node, parent depth).  First-occurrence argmin over source nodes —
    identical tie order to the dense flat-state column argmin (see module
    comment).  One O(N) scan per backtracked step (the dense lazy path scans
    O(S) = O(N G)).
    """
    st = st_l[:, n]                                      # (N,)
    finite = np.isfinite(st)
    sti = np.where(finite, st, 0).astype(np.int64)
    gsrc = g - sti
    ok = finite & (gsrc >= 0)
    if lo is not None:
        ok &= (g >= lo) | (sti == 0)
    cand = np.where(ok, dist_prev[np.arange(len(st)), np.where(ok, gsrc, 0)]
                    + E_l[:, n], np.inf)
    pn = int(np.argmin(cand))
    return pn, g - int(sti[pn])


@functools.partial(jax.jit, static_argnames=("lo",))
def _banded_relax_scan_jnp(init: jnp.ndarray, E: jnp.ndarray,
                           st: jnp.ndarray, lo: Optional[int]):
    """jit core of the banded jnp engine (float32, argmin parents).

    init: (B, N, G+1); E: (B, L, N, N) f32 (inf = pruned); st: (B, L, N, N)
    int32 (0 where pruned — E's inf kills those candidates).  Returns
    (hist (B, L+1, N, G+1), par_n (B, L, N, G+1) int32, -1 unreachable).
    """
    B, N, Gp1 = init.shape
    g = jnp.arange(Gp1)

    def step(dist, layer):
        e, s = layer                                      # (B, N, N) each
        gsrc = g[None, None, None, :] - s[..., None]      # (B, N, N, G+1)
        ok = gsrc >= 0
        if lo is not None:
            ok &= (g[None, None, None, :] >= lo) | (s[..., None] == 0)
        gat = jnp.take_along_axis(
            dist[:, :, None, :],
            jnp.clip(gsrc, 0, Gp1 - 1), axis=3)
        cand = jnp.where(ok, gat + e[..., None], jnp.inf)
        new = jnp.min(cand, axis=1)                       # (B, N, G+1)
        arg = jnp.argmin(cand, axis=1).astype(jnp.int32)
        return new, (new, jnp.where(jnp.isfinite(new), arg, -1))

    _, (h, p) = jax.lax.scan(step, init,
                             (jnp.moveaxis(E, 1, 0), jnp.moveaxis(st, 1, 0)))
    hist = jnp.concatenate([init[:, None], jnp.moveaxis(h, 0, 1)], axis=1)
    return hist, jnp.moveaxis(p, 0, 1)


def batched_banded_relax_argmin(init: np.ndarray, E: np.ndarray,
                                steep: np.ndarray, lo: Optional[int] = None,
                                backend: str = "jnp"
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """Banded relaxation with argmin-over-source-node parents.

    init: (B, N, G+1); E/steep: (B, L, N, N) (steep: int values or inf).
    Returns (hist (B, L+1, N, G+1) float64, par_n (B, L, N, G+1) int64, -1
    where unreachable).  The parent *depth* is implied: g_src = g -
    steep[par_n, n].  Backends: ``jnp`` (float32 lax.scan) and ``pallas``
    (the banded minplus kernel, one launch per layer).
    """
    B, N, Gp1 = init.shape
    L = E.shape[1]
    if L == 0:
        return (np.asarray(init)[:, None].astype(np.float64),
                np.zeros((B, 0, N, Gp1), dtype=np.int64))
    finite = np.isfinite(steep)
    sti = np.where(finite, steep, 0).astype(np.int32)
    Ef = np.where(finite, E, np.inf).astype(np.float32)
    initf = np.asarray(init, np.float32)
    if backend == "jnp":
        hist, par = _banded_relax_scan_jnp(jnp.asarray(initf),
                                           jnp.asarray(Ef), jnp.asarray(sti),
                                           lo)
        return (np.asarray(hist, np.float64),
                np.asarray(par).astype(np.int64))
    if backend == "pallas":
        from repro.kernels.minplus.ops import banded_minplus_chain
        # one chained launch relaxes the whole (B, L) batch — the distance
        # grid is carried in VMEM across layers instead of round-tripping
        # through HBM between per-layer kernel calls
        h, p = banded_minplus_chain(jnp.asarray(initf), jnp.asarray(Ef),
                                    jnp.asarray(sti), lo=lo)
        hist = np.concatenate([np.asarray(init, np.float64)[:, None],
                               np.asarray(h, np.float64)], axis=1)
        return hist, np.asarray(p).astype(np.int64)
    raise ValueError(f"unknown banded backend {backend!r}")
