"""Training launcher:  PYTHONPATH=src python -m repro.launch.train \
    --arch qwen3-4b [--reduced] --steps 100 --batch 8 --seq 128

On this CPU container use --reduced (same-family small config); the full
configs are exercised via the dry-run (launch/dryrun.py).  On a real pod the
same entry point shards the train state over the production mesh.
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_NAMES, get
from repro.runtime.train_loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch, reduced=args.reduced)
    if not args.reduced:
        print("WARNING: full config on this host — expect to OOM; "
              "use the dry-run for full-scale validation")
    res = train(cfg, n_steps=args.steps, global_batch=args.batch,
                seq_len=args.seq, ckpt_dir=args.ckpt, seed=args.seed)
    print(f"done: {res.steps} steps, loss {res.losses[0]:.4f} -> "
          f"{res.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
