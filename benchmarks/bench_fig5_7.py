"""Figs. 5 & 7: total energy of Opt / MCP / FIN(gamma=3,10) vs (delta, alpha).

Fig. 5 uses B-AlexNet (h2, CIFAR10); Fig. 7 uses B-LeNet (h6, EMNIST).
Also validates the paper's headline claims:
  * FIN(gamma=10) matches Opt (within the 1+1/gamma competitive ratio);
  * FIN(gamma=3) still never loses to MCP;
  * tighter latency targets force split deployments with higher energy.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import (AppRequirements, paper_profile, solve_fin, solve_mcp,
                        solve_opt)
from repro.core.scenarios import paper_scenario

from .common import Row, kv, timed

#: (figure, app, accuracy targets, latency targets ms)
SWEEPS = [
    ("fig5", "h2", (0.55, 0.80), (2.0, 5.0, 8.0, 12.0)),
    ("fig7", "h6", (0.93, 0.99), (0.05, 0.1, 0.5, 1.0)),
]


def run() -> List[Row]:
    nw = paper_scenario()
    rows: List[Row] = []
    for fig, app, alphas, deltas in SWEEPS:
        prof = paper_profile(app)
        for alpha in alphas:
            for delta_ms in deltas:
                req = AppRequirements(alpha=alpha, delta=delta_ms * 1e-3)
                opt, us_o = timed(solve_opt, nw, prof, req)
                fin10, us_f10 = timed(solve_fin, nw, prof, req, gamma=10)
                fin3, us_f3 = timed(solve_fin, nw, prof, req, gamma=3)
                mcp, us_m = timed(solve_mcp, nw, prof, req)

                def e(sol):
                    return sol.energy * 1e3 if sol.feasible else float("nan")

                def place(sol):
                    if not sol.feasible:
                        return "-"
                    h = sol.config.tier_histogram(nw)
                    return f"{h.get('mobile',0)}|{h.get('edge',0)}|{h.get('cloud',0)}"

                rows.append(Row(
                    f"{fig}/{app}/a{alpha}/d{delta_ms}ms", us_f10,
                    kv(opt_mJ=e(opt), fin10_mJ=e(fin10), fin3_mJ=e(fin3),
                       mcp_mJ=e(mcp), fin10_place=place(fin10),
                       opt_place=place(opt), mcp_place=place(mcp),
                       fin10_exit=(fin10.config.final_exit + 1
                                   if fin10.feasible else -1))))
                # competitive-ratio check recorded inline
                if opt.feasible and fin10.feasible:
                    assert fin10.energy <= opt.energy * 1.1 + 1e-15
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
