"""Serving launcher:  PYTHONPATH=src python -m repro.launch.serve \
    --arch qwen3-4b --requests 16 --max-new 8 [--threshold 0.7]

Runs the split-serving engine (exit-aware continuous batching) on the
reduced config with a FIN placement over the paper's mobile-edge-cloud
system, and reports throughput / exit usage / placement-model energy.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_NAMES, get
from repro.core import AppRequirements, paper_profile
from repro.core.scenarios import paper_scenario
from repro.models import transformer as T
from repro.runtime.serve_engine import SplitServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--threshold", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get(args.arch, reduced=True)
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} is encoder-only; no serve path")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    eng = SplitServeEngine(
        cfg, params, batch_size=args.batch, cache_len=256,
        thresholds=[args.threshold] * (len(cfg.exit_layer_list)),
        network=paper_scenario(), profile=paper_profile("h2"),
        req=AppRequirements(alpha=0.55, delta=8e-3))
    for i in range(args.requests):
        eng.submit([1 + i % 7, 2, 3], max_new_tokens=args.max_new)
    stats = eng.run()
    print(f"steps={stats.steps} tokens={stats.tokens_out} "
          f"phi={stats.measured_phi} energy={stats.energy_j*1e3:.2f}mJ "
          f"blocks saved={stats.blocks_saved}")


if __name__ == "__main__":
    main()
