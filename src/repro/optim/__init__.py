"""Optimizers and gradient utilities (pure JAX)."""
from .adamw import (AdamW, AdamWState, clip_by_global_norm, compress_grads,
                    cosine_schedule, decompress_grads, global_norm)

__all__ = ["AdamW", "AdamWState", "clip_by_global_norm", "compress_grads",
           "cosine_schedule", "decompress_grads", "global_norm"]
