"""MeshRelaxer shaping contract: pad-and-strip for ragged scenario counts,
clear ValueErrors for malformed stacks, and f32 agreement with the float64
reference on every branch.

Runs against however many devices are visible; the pad-branch tests need a
multi-device mesh and are exercised with 4 host devices via
``tests/test_stream_subprocess.py`` (the main pytest process keeps the
default single CPU device).
"""
import jax
import numpy as np
import pytest

from repro.core.bellman_ford import batched_banded_relax_minarg
from repro.sharding.population import MeshRelaxer, population_mesh


def _case(D, seed=0, L=3, N=5, Gp1=11):
    rng = np.random.default_rng(seed)
    steep = np.where(rng.random((D, L, N, N)) < 0.5,
                     rng.integers(0, Gp1 - 1, (D, L, N, N)).astype(float),
                     np.inf)
    E = rng.random((D, L, N, N))
    init = np.where(rng.random((D, N, Gp1)) < 0.3,
                    rng.random((D, N, Gp1)), np.inf)
    return init, E, steep


def _check(mr, D, seed=0):
    init, E, steep = _case(D, seed)
    h, p = mr.relax(init, E, steep, None)
    assert h.shape == (D, 4, 5, 11)
    assert p.shape == (D, 3, 5, 11)
    h64, _ = batched_banded_relax_minarg(
        init, np.where(np.isfinite(steep), E, np.inf), steep, None)
    fin = np.isfinite(h64)
    assert np.array_equal(np.isfinite(h), fin)
    np.testing.assert_allclose(h[fin], h64[fin], rtol=1e-6)
    assert np.array_equal(h[:, 0], init)      # exact f64 init row


def test_divisible_counts_no_padding():
    mr = MeshRelaxer(population_mesh())
    _check(mr, 2 * mr.n_devices, seed=1)


def test_ragged_counts_pad_and_strip():
    mr = MeshRelaxer(population_mesh())
    for D in (1, mr.n_devices + 1, 3 * mr.n_devices - 1):
        _check(mr, D, seed=D)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="pad branch needs a multi-device mesh")
def test_pad_branch_on_multi_device_mesh():
    mr = MeshRelaxer(population_mesh(4))
    assert mr.n_devices == 4
    for D in (1, 3, 5, 7):                    # all force padding
        assert D % mr.n_devices != 0
        _check(mr, D, seed=10 + D)
    _check(mr, 8, seed=99)                    # and the exact-fit branch


def test_malformed_stacks_raise():
    mr = MeshRelaxer(population_mesh())
    init, E, steep = _case(4)
    with pytest.raises(ValueError, match="init must be"):
        mr.relax(init[:, 0], E, steep, None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E[:, :, :4], steep, None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E, steep[:2], None)
    with pytest.raises(ValueError, match="E/steep"):
        mr.relax(init, E[:2], steep[:2], None)


def test_zero_layer_chain_short_circuits():
    mr = MeshRelaxer(population_mesh())
    init, _, _ = _case(3)
    h, p = mr.relax(init, np.empty((3, 0, 5, 5)), np.empty((3, 0, 5, 5)),
                    None)
    assert np.array_equal(h[:, 0], init) and p.shape == (3, 0, 5, 11)


def test_population_mesh_device_trim_validation():
    with pytest.raises(ValueError, match="visible"):
        population_mesh(jax.device_count() + 1)
