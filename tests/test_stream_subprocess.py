"""Multi-device and multi-process mesh coverage, run out-of-process.

The main pytest process keeps the default single CPU device (smoke tests
must not see a forced device count), so the MeshRelaxer pad-branch suite
runs in its own interpreter with ``XLA_FLAGS`` set before jax imports, and
the simulated multi-host smoke launches a 2-process ``jax.distributed``
cluster over the loopback coordinator — no real cluster needed.
"""
import os
import pathlib
import socket
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.timeout(600)
def test_mesh_relaxer_suite_with_host_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(REPO / "tests" / "test_mesh_relaxer.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=580)
    tail = "\n".join((r.stdout + r.stderr).splitlines()[-25:])
    assert r.returncode == 0, f"mesh relaxer suite failed:\n{tail}"


@pytest.mark.timeout(600)
def test_simulated_multihost_two_processes():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = str(REPO / "src")
    worker = str(REPO / "tests" / "multihost_worker.py")
    procs = [subprocess.Popen(
                [sys.executable, worker, str(i), "2", str(port)],
                env=env, cwd=REPO, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True)
             for i in range(2)]
    outs = []
    for p in procs:
        try:
            out, _ = p.communicate(timeout=560)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append((p.returncode, out))
    for i, (rc, out) in enumerate(outs):
        tail = "\n".join(out.splitlines()[-20:])
        assert rc == 0, f"multihost worker {i} failed:\n{tail}"
        assert f"proc {i}:" in out and "exact" in out
