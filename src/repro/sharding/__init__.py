"""Partition-spec policies for the production mesh, plus the population
device-mesh execution layer (user-axis sharded banded relaxations)."""
from .population import MeshRelaxer, population_mesh
from .specs import (batch_shardings, cache_spec, caches_shardings, dp_axes,
                    param_spec, params_shardings, scalar_sharding)

__all__ = ["batch_shardings", "cache_spec", "caches_shardings", "dp_axes",
           "param_spec", "params_shardings", "scalar_sharding",
           "MeshRelaxer", "population_mesh"]
