"""Shared helpers for the benchmark harness.

Every bench function yields ``Row(name, us_per_call, derived)`` records; the
``derived`` field carries the paper-facing metric (energy, latency, ratio...)
as a compact ``key=value;...`` string so ``run.py`` can emit a uniform CSV
(or, with ``--json``, machine-readable records with the key-values parsed).

``run.py --smoke`` sets REPRO_BENCH_SMOKE=1; benches consult ``smoke()`` to
shrink instance sizes / repeat counts for the CI perf-regression smoke job.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List


def smoke() -> bool:
    """True when running as the reduced-size CI smoke pass."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"

    def to_dict(self) -> Dict[str, object]:
        """JSON-friendly record: the ``key=value;...`` payload is parsed and
        numeric values converted, so downstream tooling (BENCH_PR2.json,
        regression checks) can compare fields without re-parsing CSV."""
        out: Dict[str, object] = {"name": self.name,
                                  "us_per_call": round(self.us_per_call, 3)}
        for part in self.derived.split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            try:
                out[k] = int(v) if v.lstrip("+-").isdigit() else float(v)
            except ValueError:
                out[k] = v
        return out


def timed(fn: Callable, *args, repeats: int = 3, **kwargs):
    """Run fn repeatedly; return (last_result, best_us)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def kv(**kwargs) -> str:
    parts = []
    for k, v in kwargs.items():
        if isinstance(v, float):
            parts.append(f"{k}={v:.6g}")
        else:
            parts.append(f"{k}={v}")
    return ";".join(parts)


def batched_solver_row(name: str, profiles, networks, reqs, *,
                       gamma: int = 10, repeats: int = 1, **extra) -> Row:
    """Time one ``solve_many`` batched relaxation against the equivalent loop
    of legacy ``backend="python"`` ``solve_fin`` calls.

    Shared by every batched-solver benchmark mode so the timing protocol
    (full-size warmup, interleaved best-of-N so scheduler noise hits both
    paths alike) and the agreement check (placement AND energy per scenario)
    cannot drift between benches.  ``networks``/``reqs`` broadcast like
    ``solve_many``'s arguments.  Extra keyword args land in the kv payload.
    """
    from repro.core import solve_fin, solve_many

    B = max(len(x) if isinstance(x, (list, tuple)) else 1
            for x in (profiles, networks, reqs))

    def aslist(x):
        xs = list(x) if isinstance(x, (list, tuple)) else [x]
        return xs * B if len(xs) == 1 else xs

    ps, ns, rs = aslist(profiles), aslist(networks), aslist(reqs)

    # full-size warmup (allocator pages, profile caches)
    batched = solve_many(ps, ns, rs, gamma=gamma)
    legacy = [solve_fin(nw, pf, rq, gamma=gamma, backend="python")
              for pf, nw, rq in zip(ps, ns, rs)]
    t_legacy = t_batched = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        legacy = [solve_fin(nw, pf, rq, gamma=gamma, backend="python")
                  for pf, nw, rq in zip(ps, ns, rs)]
        t_legacy = min(t_legacy, time.perf_counter() - t0)
        t0 = time.perf_counter()
        batched = solve_many(ps, ns, rs, gamma=gamma)
        t_batched = min(t_batched, time.perf_counter() - t0)

    agree = sum(
        1 for a, b in zip(legacy, batched)
        if a.found == b.found and (not a.found or
                                   (a.config.placement == b.config.placement
                                    and a.energy == b.energy)))
    return Row(name, t_batched / len(ps) * 1e6,
               kv(n_scenarios=len(ps), legacy_ms=t_legacy * 1e3,
                  batched_ms=t_batched * 1e3, speedup=t_legacy / t_batched,
                  agree=agree, **extra))
