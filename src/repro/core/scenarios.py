"""Calibrated evaluation scenarios (Sec. IV-V reference scenario).

Calibration notes (recorded per DESIGN.md Sec. 7):

* Compute slices.  With the full node TOPS of Sec. IV, every paper DNN
  executes in microseconds and placement is trivial.  The paper's Fig. 4
  reports 6.56 ms for all-blocks-on-mobile B-AlexNet and 39.4 mJ = 6 W x
  6.56 ms — i.e. the *per-application compute slice* c^h of the mobile node
  is total_path_ops / 6.56 ms ~= 1.39e10 ops/s (0.126% of 11 TOPS).  We use
  exactly that slice for the mobile tier and the multi-app 0.5% slice for
  edge/cloud.
* Mobile uplink.  Table V's 0.1 Gb/s with 8-bit cut tensors makes *every*
  B-AlexNet split infeasible at delta = 5 ms (the after-block-2 cut alone is
  5.2 ms), yet Fig. 5 reports split deployments at that target.  The paper's
  numbers imply an effective ~1 Gb/s mobile uplink (equivalently, 8x
  BottleFit-style compression at the cut).  ``paper_scenario`` defaults to
  1 Gb/s and keeps everything else at Table V values.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .dnn_profile import DNNProfile, all_paper_apps, paper_profile
from .problem import AppRequirements
from .system_model import Network, make_network

#: mobile per-app compute slice calibrated on Fig. 4 (see module docstring).
MOBILE_SLICE_FRAC = 1.389e10 / 11e12        # 0.1263% of 11 TOPS
EDGE_SLICE_FRAC = 0.005                     # Sec. V multi-app slice
CLOUD_SLICE_FRAC = 0.005
MOBILE_UPLINK_BPS = 1e9                     # calibrated (see docstring)


def paper_scenario(*, uplink_bps: float = MOBILE_UPLINK_BPS,
                   mobile_frac: float = MOBILE_SLICE_FRAC,
                   edge_frac: float = EDGE_SLICE_FRAC,
                   cloud_frac: float = CLOUD_SLICE_FRAC,
                   n_extra_edge: int = 0) -> Network:
    """The single-application evaluation network of Figs. 4-7.

    ``n_extra_edge > 0`` densifies the edge tier with that many additional
    edge nodes (same per-app slice) — the multi-helper infrastructure flavour
    of Sec. V, used by the batched scenario-sweep benchmarks where placement
    search spans many candidate hosts."""
    tiers = ("mobile", "edge") + ("edge",) * n_extra_edge + ("cloud",)
    fracs = (mobile_frac, edge_frac) + (edge_frac,) * n_extra_edge + (cloud_frac,)
    nw = make_network(tiers, compute_frac=fracs)
    bw = nw.bandwidth.copy()
    bw[0, 1:] = uplink_bps
    bw[1:, 0] = uplink_bps
    np.fill_diagonal(bw, np.inf)
    return Network(nodes=nw.nodes, bandwidth=bw, compute=nw.compute,
                   source_node=0)


def paper_apps() -> Dict[str, DNNProfile]:
    return all_paper_apps()


def sweep_scenarios(*, apps: Sequence[str] = ("h1", "h2", "h3", "h4", "h5",
                                              "h6"),
                    deltas_ms: Sequence[float] = (2.0, 5.0, 8.0, 12.0),
                    alphas: Optional[Sequence[float]] = None,
                    uplinks_bps: Sequence[float] = (MOBILE_UPLINK_BPS,),
                    n_extra_edge: int = 0
                    ) -> Tuple[List[DNNProfile], List[Network],
                               List[AppRequirements]]:
    """Cartesian (app x delta x alpha x uplink) scenario grid for batched
    Fig. 5-7 style sweeps — parallel lists ready for ``fin.solve_many``.

    ``alphas=None`` uses each app's always-satisfiable floor (its weakest
    exit accuracy), so every scenario exercises the full placement search.
    Networks are shared across scenarios per uplink setting, which lets the
    batched solver dedupe the extended-graph construction.
    """
    profiles = paper_apps()
    nets = {u: paper_scenario(uplink_bps=u, n_extra_edge=n_extra_edge)
            for u in uplinks_bps}
    ps: List[DNNProfile] = []
    ns: List[Network] = []
    rs: List[AppRequirements] = []
    for app in apps:
        prof = profiles[app]
        app_alphas = ([min(e.accuracy for e in prof.exits)] if alphas is None
                      else alphas)
        for u in uplinks_bps:
            for alpha in app_alphas:
                for d in deltas_ms:
                    ps.append(prof)
                    ns.append(nets[u])
                    rs.append(AppRequirements(alpha=alpha, delta=d * 1e-3,
                                              sigma=1.0))
    return ps, ns, rs


# ---------------------------------------------------------------------------
# Churn traces (online regime: mobility, fading, failures)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChurnEvent:
    """One churn event of an online trace.

    kind:
      ``uplink``   per-user channel draw; ``value`` is the quality factor in
                   [0, 1] (the orchestrator scales it by its base uplink);
      ``attach``   mobility re-association; ``value`` is the *edge slot*
                   index (0..n_edge-1) the user now attaches to — the
                   orchestrator maps slots to its network's edge nodes;
      ``fail`` / ``recover``  node failure / recovery; ``value`` is the node
                   index.  ``user=None`` means an infrastructure event that
                   applies to every user's plan;
      ``slice``    slice re-negotiation; ``value`` is the compute fraction.
    """

    kind: str
    user: Optional[int]
    value: Union[float, int]


def churn_trace(n_users: int, n_ticks: int, *, seed: int = 0,
                rho: float = 0.95, sigma: float = 0.05,
                q_mean: float = 0.65, q_lo: float = 0.3, q_hi: float = 1.0,
                p_fail: float = 0.0, p_recover: float = 0.5,
                fail_nodes: Sequence[int] = (1,),
                p_move: float = 0.0, n_edge: int = 1,
                failure_mode: str = "iid",
                tier_groups: Optional[Sequence[Sequence[int]]] = None,
                ) -> List[List[ChurnEvent]]:
    """Per-tick churn events for a user population (Sec. V online regime).

    Channel fading is a Gauss-Markov (AR(1)) process per user — quality
    q_{t+1} = q_mean + rho (q_t - q_mean) + N(0, sigma), clipped to
    [q_lo, q_hi] — the standard mobile-channel shadowing model; ``rho``
    close to 1 gives slowly varying channels whose *quantized* solver
    tensors change only when a fade crosses a quantization cell (the
    regime the incremental ``Plan`` layer exploits).  ``p_fail`` /
    ``p_recover`` drive infrastructure node failures and recoveries on
    ``fail_nodes``; ``p_move`` re-associates a user to a uniformly drawn
    edge slot (mobility across ``n_edge`` helpers).  Deterministic per
    seed; every tick emits one ``uplink`` event per user.

    ``failure_mode`` picks the outage structure:

    ``"iid"``   (default) one independent Markov chain per node of
                ``fail_nodes`` — uncorrelated single-node failures;
    ``"tier"``  one Markov chain per *group* of ``tier_groups`` (default:
                all of ``fail_nodes`` as one group) — a group fails and
                recovers jointly, emitting one event per member in the
                same tick.  This is the correlated regional-outage model
                (a rack / power-domain / backhaul-segment outage takes a
                whole tier down at once), the failure masks the
                contingency library's per-tier candidates precompute.
    """
    if failure_mode not in ("iid", "tier"):
        raise ValueError(f"failure_mode must be 'iid' or 'tier', got "
                         f"{failure_mode!r}")
    if tier_groups is not None and failure_mode != "tier":
        raise ValueError("tier_groups= only applies with "
                         "failure_mode='tier'")
    rng = np.random.default_rng(seed)
    q = np.full(n_users, q_mean)
    if failure_mode == "tier":
        groups: List[Tuple[int, ...]] = (
            [tuple(int(n) for n in fail_nodes)] if tier_groups is None
            else [tuple(int(n) for n in g) for g in tier_groups])
    else:
        groups = [(int(n),) for n in fail_nodes]
    failed: Dict[int, bool] = {g: False for g in range(len(groups))}
    trace: List[List[ChurnEvent]] = []
    for _ in range(n_ticks):
        events: List[ChurnEvent] = []
        q = np.clip(q_mean + rho * (q - q_mean)
                    + rng.normal(0.0, sigma, n_users), q_lo, q_hi)
        events.extend(ChurnEvent("uplink", u, float(q[u]))
                      for u in range(n_users))
        if p_move > 0 and n_edge > 1:
            movers = np.nonzero(rng.random(n_users) < p_move)[0]
            for u in movers:
                events.append(ChurnEvent("attach", int(u),
                                         int(rng.integers(n_edge))))
        for g, nodes in enumerate(groups):
            if failed[g]:
                if rng.random() < p_recover:
                    failed[g] = False
                    events.extend(ChurnEvent("recover", None, node)
                                  for node in nodes)
            elif p_fail > 0 and rng.random() < p_fail:
                failed[g] = True
                events.extend(ChurnEvent("fail", None, node)
                              for node in nodes)
        trace.append(events)
    return trace


#: Table VI example configurations (block counts per tier) for Fig. 4.
#: Config-1: all on mobile; Config-2: [l1,e1,l2 | l3,e2,l4,l5,e3 | -];
#: Config-3: [l1,e1,l2 | l3,e2,l4 | l5,e3].
TABLE_VI_CONFIGS = {
    "config-1": [0, 0, 0, 0, 0],
    "config-2": [0, 0, 1, 1, 1],
    "config-3": [0, 0, 1, 1, 2],
}
