"""Jitted wrapper for the minplus Pallas kernel.

``interpret=True`` executes the kernel body in Python on CPU (this
container); on TPU set interpret=False for the compiled Mosaic kernel."""
from __future__ import annotations

import jax.numpy as jnp

from .minplus import minplus_pallas


def minplus_vecmat(dist: jnp.ndarray, W: jnp.ndarray, *,
                   interpret: bool = True) -> jnp.ndarray:
    """dist: [B, S] float; W: [S, T] float (inf = no edge) -> [B, T]."""
    return minplus_pallas(dist, W, interpret=interpret)
