"""Runs tests/test_sharding.py in a subprocess with 8 host devices.

The main pytest process keeps the default single CPU device (smoke tests
must not see a forced device count); the sharding suite needs a mesh, so it
runs in its own interpreter with XLA_FLAGS set before jax imports.
"""
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


@pytest.mark.timeout(900)
def test_sharding_suite_with_host_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(REPO / "tests" / "test_sharding.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=880)
    tail = "\n".join((r.stdout + r.stderr).splitlines()[-25:])
    assert r.returncode == 0, f"sharding suite failed:\n{tail}"
