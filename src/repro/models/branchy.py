"""The paper's branchy DNNs in JAX: B-LeNet, B-AlexNet, B-ResNet (Sec. IV).

A :class:`BranchyModel` is a chain of backbone blocks; some blocks carry an
early-exit head.  The functional API:

  params = model.init(key)
  logits_per_exit, feats = model.apply(params, x)          # all exits
  y, exit_idx = model.infer(params, x, thresholds)         # gated inference
  profile = model.extract_profile(...)                     # -> core.DNNProfile

Block boundaries and feature-map sizes follow Table III: each block's output
feature count matches the paper's "number of features" column exactly (that
column is the block *output*: 290400 = 55x55x96 for B-AlexNet conv1 etc.).
Exit placement follows Table VI (exits with blocks 1, 3, 5 for AlexNet and
ResNet; BranchyNet placement for LeNet).  ``extract_profile`` turns the real
JAX model into a Plane-2 ``DNNProfile`` with true MAC counts — the measured
alternative to the paper's Table III ops (which count k^2*H*W*C_out only;
see benchmarks/bench_table3.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .cnn_layers import (Conv, Dense, Flatten, GlobalAvgPool, MaxPool,
                         Residual, Sequential, Shape)


@dataclass(frozen=True)
class BranchyModel:
    name: str
    input_shape: Shape                    # (H, W, C)
    blocks: Tuple[Sequential, ...]        # backbone blocks
    exits: Dict[int, Sequential]          # block idx -> exit head
    n_classes: int

    # -- parameters -----------------------------------------------------------
    def init(self, key):
        params = {"blocks": [], "exits": {}}
        shape = self.input_shape
        keys = jax.random.split(key, len(self.blocks) + len(self.exits))
        ki = 0
        for i, blk in enumerate(self.blocks):
            p, shape_out = blk.init(keys[ki], shape)
            ki += 1
            params["blocks"].append(p)
            if i in self.exits:
                pe, _ = self.exits[i].init(keys[ki], shape_out)
                ki += 1
                params["exits"][str(i)] = pe
            shape = shape_out
        return params

    # -- forward --------------------------------------------------------------
    def apply(self, params, x, *, up_to_block: Optional[int] = None):
        """Run blocks 0..up_to_block; return ({block_idx: exit_logits}, feats)."""
        last = len(self.blocks) - 1 if up_to_block is None else up_to_block
        logits: Dict[int, jnp.ndarray] = {}
        h = x
        for i in range(last + 1):
            h = self.blocks[i].apply(params["blocks"][i], h)
            if i in self.exits:
                logits[i] = self.exits[i].apply(params["exits"][str(i)], h)
        return logits, h

    def exit_blocks(self) -> List[int]:
        return sorted(self.exits.keys())

    # -- gated inference (per-sample dynamic depth) -----------------------------
    def infer(self, params, x, thresholds: Sequence[float]):
        """Confidence-gated early-exit inference.

        A sample exits at the first exit whose max-softmax confidence clears
        its threshold.  Returns (predictions, exit_index_per_sample).  All
        exits are computed (SPMD semantics); the *placement* problem is what
        turns the phi fractions into saved energy (DESIGN.md Sec. 3).
        """
        logits, _ = self.apply(params, x)
        eb = self.exit_blocks()
        assert len(thresholds) >= len(eb) - 1
        B = x.shape[0]
        pred = jnp.zeros(B, dtype=jnp.int32)
        exit_idx = jnp.full(B, len(eb) - 1, dtype=jnp.int32)
        decided = jnp.zeros(B, dtype=bool)
        for j, b in enumerate(eb):
            p = jax.nn.softmax(logits[b], axis=-1)
            conf = p.max(axis=-1)
            is_last = j == len(eb) - 1
            take = (~decided) & (jnp.ones(B, bool) if is_last
                                 else conf >= thresholds[j])
            pred = jnp.where(take, p.argmax(axis=-1).astype(jnp.int32), pred)
            exit_idx = jnp.where(take, j, exit_idx)
            decided = decided | take
        return pred, exit_idx

    def loss(self, params, x, labels, exit_weights: Optional[Sequence[float]] = None):
        """BranchyNet joint loss: weighted sum of per-exit cross-entropies."""
        logits, _ = self.apply(params, x)
        eb = self.exit_blocks()
        w = ([1.0] * len(eb)) if exit_weights is None else list(exit_weights)
        total = 0.0
        for j, b in enumerate(eb):
            logp = jax.nn.log_softmax(logits[b], axis=-1)
            ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
            total = total + w[j] * ce
        return total / sum(w)

    # -- profile extraction -----------------------------------------------------
    def extract_profile(self, *, bits_per_feature: int = 8,
                        accuracies: Optional[Sequence[float]] = None,
                        phis: Optional[Sequence[float]] = None):
        """Measured Plane-2 profile: true MACs + true cut sizes from the model."""
        from repro.core.dnn_profile import DNNProfile, ExitSpec

        shape = self.input_shape
        block_ops, cut_bits, shapes = [], [], []
        for blk in self.blocks:
            block_ops.append(blk.macs(shape))
            shape = blk.out_shape(shape)
            shapes.append(shape)
            cut_bits.append(float(np.prod(shape)) * bits_per_feature)
        eb = self.exit_blocks()
        n_e = len(eb)
        acc = list(accuracies) if accuracies is not None else \
            list(np.linspace(0.5, 0.9, n_e))
        phi = list(phis) if phis is not None else [1.0 / n_e] * n_e
        exits = []
        for j, b in enumerate(eb):
            head = self.exits[b]
            exits.append(ExitSpec(
                block=b, ops=head.macs(shapes[b]),
                out_bits=self.n_classes * bits_per_feature,
                accuracy=float(acc[j]), phi=float(phi[j])))
        return DNNProfile(name=f"{self.name}:measured",
                          input_bits=float(np.prod(self.input_shape)) * bits_per_feature,
                          block_ops=block_ops, cut_bits=cut_bits, exits=exits)


# ---------------------------------------------------------------------------
# Model definitions (Table III feature-count-faithful)
# ---------------------------------------------------------------------------

def b_lenet(n_classes: int = 10) -> BranchyModel:
    """B-LeNet: 2 conv + 2 pool + 3 FC backbone, 1 early exit (2 exits total).

    Block outputs: 28x28x6 = 4704, 10x10x16 = 1600, 120 (Table III)."""
    blocks = (
        Sequential((Conv(6, 5, 1, "SAME"),)),                     # -> 4704
        Sequential((MaxPool(2, 2), Conv(16, 5, 1, "VALID"))),      # -> 1600
        Sequential((MaxPool(2, 2), Flatten(), Dense(120, use_relu=True))),
    )
    exits = {
        0: Sequential((MaxPool(4, 4), Flatten(), Dense(n_classes))),
        2: Sequential((Dense(84, use_relu=True), Dense(n_classes))),
    }
    return BranchyModel("b-lenet", (28, 28, 1), blocks, exits, n_classes)


def b_alexnet(n_classes: int = 10) -> BranchyModel:
    """B-AlexNet: 5 conv blocks, exits at blocks 1, 3, 5 (Table VI).

    Block outputs: 55x55x96 = 290400, 27x27x256 = 186624, 13x13x384 = 64896,
    13x13x384 = 64896, 13x13x256 = 43264 (Table III)."""
    blocks = (
        Sequential((Conv(96, 11, 4, "VALID"),)),                   # 55x55x96
        Sequential((MaxPool(3, 2), Conv(256, 5, 1, "SAME"))),       # 27x27x256
        Sequential((MaxPool(3, 2), Conv(384, 3, 1, "SAME"))),       # 13x13x384
        Sequential((Conv(384, 3, 1, "SAME"),)),                     # 13x13x384
        Sequential((Conv(256, 3, 1, "SAME"),)),                     # 13x13x256
    )
    exits = {
        0: Sequential((MaxPool(3, 2), Conv(96, 3, 1, "SAME"),
                       GlobalAvgPool(), Dense(n_classes))),
        2: Sequential((Conv(256, 3, 1, "SAME"), GlobalAvgPool(),
                       Dense(n_classes))),
        4: Sequential((GlobalAvgPool(), Dense(n_classes))),
    }
    return BranchyModel("b-alexnet", (227, 227, 3), blocks, exits, n_classes)


def b_resnet(n_classes: int = 10, *, blocks_per_stage: int = 2) -> BranchyModel:
    """B-ResNet: CIFAR ResNet backbone in 5 blocks, exits at 1, 3, 5.

    Block outputs: 32x32x16 = 16384 (x3), 8x8x64 = 4096 (x2), per Table III.
    ``blocks_per_stage=18`` gives the full ResNet-110; the default keeps CPU
    tests fast (depth is a config knob, not an architecture change)."""
    n = blocks_per_stage
    stage1a = tuple([Conv(16, 3, 1, "SAME")] + [Residual(16)] * n)
    stage1b = tuple([Residual(16)] * n)
    stage1c = tuple([Residual(16)] * n)
    stage23 = tuple([Residual(32, stride=2)] + [Residual(32)] * (n - 1)
                    + [Residual(64, stride=2)] + [Residual(64)] * (n - 1))
    stage3b = tuple([Residual(64)] * n)
    blocks = (
        Sequential(stage1a),    # 32x32x16 = 16384
        Sequential(stage1b),    # 16384
        Sequential(stage1c),    # 16384
        Sequential(stage23),    # 8x8x64 = 4096
        Sequential(stage3b),    # 4096
    )
    exits = {
        0: Sequential((GlobalAvgPool(), Dense(n_classes))),
        2: Sequential((GlobalAvgPool(), Dense(n_classes))),
        4: Sequential((GlobalAvgPool(), Dense(n_classes))),
    }
    return BranchyModel("b-resnet", (32, 32, 3), blocks, exits, n_classes)


PAPER_MODELS = {"b-lenet": b_lenet, "b-alexnet": b_alexnet, "b-resnet": b_resnet}
#: Table III block output feature counts, for validation.
TABLE_III_FEATURES = {
    "b-lenet": [4704, 1600, 120],
    "b-alexnet": [290400, 186624, 64896, 64896, 43264],
    "b-resnet": [16384, 16384, 16384, 4096, 4096],
}
